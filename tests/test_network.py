"""Network-level event-driven engine (core/network.py).

Covers the ISSUE-1 acceptance properties: scheduler determinism under a
fixed seed, standalone-vs-annotation mode consistency, and network-level
LASANA-vs-behavioral spike-train parity within the paper tolerance (<2%
behavioral error) on a tiny 2-layer net — plus mesh batch-parallel parity
and report aggregation invariants.

ISSUE-2 adds the heterogeneous graph coverage: crossbar->LIF mixed-circuit
parity, recurrent-edge one-tick delay semantics, typed inter-layer adapter
shape/dtype round-trips, edge validation, and per-layer circuit/backend
attribution in the report.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.network import (EdgeSpec, NetworkEngine, adapt_signal,
                                crossbar_layer, crossbar_mlp_spec,
                                event_threshold, graph_spec, lif_layer,
                                recurrent_edge, snn_spec)
from repro.core.simulate import run_snn_golden, run_snn_lasana

T_STEPS, BATCH = 40, 4


@pytest.fixture(scope="module")
def net_bank():
    """Quality LIF bank — large enough for <2% network-level parity."""
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("lif", TestbenchConfig(n_runs=600, n_steps=80, seed=1))
    return PredictorBank("lif", families=("linear", "mlp")).fit(ds)


@pytest.fixture(scope="module")
def tiny_net():
    """2-layer 12-8-4 LIF net + fixed-seed Poisson spike stimulus."""
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (12, 8)) * 0.8
    w2 = jax.random.normal(jax.random.PRNGKey(1), (8, 4)) * 0.8
    params = [jnp.asarray([0.58, 0.5, 0.5, 0.5])] * 2
    spec = snn_spec([w1, w2], params)
    spikes = (jax.random.bernoulli(jax.random.PRNGKey(2), 0.2,
                                   (T_STEPS, BATCH, 12)) * 1.5
              ).astype(jnp.float32)
    return spec, spikes


def test_scheduler_deterministic_under_fixed_seed(net_bank, tiny_net):
    """Same spec + same stimulus -> bit-identical runs, engine reuse or not."""
    spec, spikes = tiny_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=net_bank)
    r1 = eng.run(spikes)
    r2 = eng.run(spikes)                                   # cached jit
    r3 = NetworkEngine(spec, backend="lasana", surrogates=net_bank).run(spikes)
    for other in (r2, r3):
        np.testing.assert_array_equal(r1.out_spikes, other.out_spikes)
        np.testing.assert_array_equal(r1.energy, other.energy)
        np.testing.assert_array_equal(r1.events, other.events)
        np.testing.assert_array_equal(r1.flush_energy, other.flush_energy)


def test_standalone_vs_annotation_consistency(net_bank, tiny_net):
    """Annotation mode must reproduce behavioral spikes EXACTLY (it only
    adds energy/latency) and its energy must land near standalone's."""
    spec, spikes = tiny_net
    behav = NetworkEngine(spec, backend="behavioral").run(spikes)
    annot = NetworkEngine(spec, backend="lasana", surrogates=net_bank,
                          mode="annotation").run(spikes)
    stand = NetworkEngine(spec, backend="lasana", surrogates=net_bank).run(spikes)
    np.testing.assert_array_equal(annot.out_spikes, behav.out_spikes)
    for a, b in zip(annot.layer_spikes, behav.layer_spikes):
        np.testing.assert_array_equal(a, b)
    # behavioral alone reports zero energy; annotation fills it in
    assert behav.energy.sum() == 0.0
    e_a = annot.energy.sum() + annot.flush_energy.sum()
    e_s = stand.energy.sum() + stand.flush_energy.sum()
    assert np.isfinite(e_a) and e_a > 0
    assert abs(e_a - e_s) / e_s < 0.5, (e_a, e_s)


def test_lasana_behavioral_spike_parity(net_bank, tiny_net):
    """Paper tolerance: <2% spike-train mismatch across the whole net."""
    spec, spikes = tiny_net
    behav = NetworkEngine(spec, backend="behavioral").run(spikes)
    las = NetworkEngine(spec, backend="lasana", surrogates=net_bank).run(spikes)
    mism = sum(np.sum((b > 0.75) != (l > 0.75)) for b, l in
               zip(behav.layer_spikes, las.layer_spikes))
    total = sum(b.size for b in behav.layer_spikes)
    assert mism / total < 0.02, f"spike mismatch {mism / total:.4f}"


def test_lasana_energy_tracks_golden(net_bank, tiny_net):
    """Event-driven totals (incl. idle flush) land near the golden sim."""
    spec, spikes = tiny_net
    gold = NetworkEngine(spec, backend="golden").run(spikes)
    las = NetworkEngine(spec, backend="lasana", surrogates=net_bank).run(spikes)
    e_g = gold.report()["network"]["energy_j"]
    e_l = las.report()["network"]["energy_j"]
    assert abs(e_l - e_g) / e_g < 0.15, (e_l, e_g)


def test_mesh_batch_parallel_parity(net_bank, tiny_net):
    """shard_map over a 1-device mesh must not change any output."""
    spec, spikes = tiny_net
    mesh = Mesh(np.array(jax.devices()[:1]), ("batch",))
    base = NetworkEngine(spec, backend="lasana", surrogates=net_bank).run(spikes)
    shard = NetworkEngine(spec, backend="lasana", surrogates=net_bank,
                          mesh=mesh).run(spikes)
    np.testing.assert_array_equal(base.out_spikes, shard.out_spikes)
    np.testing.assert_allclose(base.energy, shard.energy, rtol=1e-6)
    np.testing.assert_allclose(base.flush_energy, shard.flush_energy,
                               rtol=1e-6)
    np.testing.assert_array_equal(base.events, shard.events)


def test_report_aggregation(net_bank, tiny_net):
    """The network report must be consistent with the raw per-tick arrays."""
    spec, spikes = tiny_net
    run = NetworkEngine(spec, backend="lasana", surrogates=net_bank).run(spikes)
    rep = run.report()
    assert len(rep["layers"]) == spec.n_layers
    for i, layer in enumerate(rep["layers"]):
        np.testing.assert_allclose(
            layer["energy_j"],
            run.energy[:, i].sum() + run.flush_energy[i], rtol=1e-6)
        assert layer["events"] == int(run.events[:, i].sum())
    np.testing.assert_allclose(
        rep["network"]["energy_j"],
        sum(l["energy_j"] for l in rep["layers"]), rtol=1e-6)
    assert rep["network"]["events"] == int(run.events.sum())
    assert rep["network"]["ticks"] == T_STEPS
    # event-driven scheduling actually skips idle circuits
    assert rep["network"]["events"] < T_STEPS * BATCH * (8 + 4)


def test_golden_backend_matches_simulate_wrapper(tiny_net):
    """The compat wrapper in simulate.py is the engine under the hood."""
    spec, spikes = tiny_net
    run = NetworkEngine(spec, backend="golden").run(spikes)
    counts, energy = run_snn_golden(
        "lif", [l.weight for l in spec.layers],
        spikes, [l.params for l in spec.layers])
    np.testing.assert_array_equal(run.outputs, counts)
    np.testing.assert_allclose(run.energy.sum(), energy, rtol=1e-6)


def test_invalid_configuration_raises(tiny_net):
    spec, spikes = tiny_net
    with pytest.raises(ValueError, match="backend"):
        NetworkEngine(spec, backend="spice")
    # surrogates may be bound at run() time, but running without any raises
    with pytest.raises(ValueError, match="PredictorBank"):
        NetworkEngine(spec, backend="lasana").run(spikes)
    with pytest.raises(ValueError, match="mode"):
        NetworkEngine(spec, backend="lasana", surrogates=object(),
                      mode="oracle")


# --- crossbar (combinational) path -------------------------------------------

@pytest.fixture(scope="module")
def xbar_net():
    rng = np.random.default_rng(7)
    ws = [rng.integers(-1, 2, (40, 8)).astype(np.float32),
          rng.integers(-1, 2, (8, 4)).astype(np.float32)]
    x = rng.uniform(-0.8, 0.8, (4, 40)).astype(np.float32)
    return crossbar_mlp_spec(ws), x


def test_crossbar_golden_vs_behavioral(xbar_net):
    """Ideal settle + ADC quantization: behavioral must equal golden."""
    spec, x = xbar_net
    g = NetworkEngine(spec, backend="golden").run(x)
    b = NetworkEngine(spec, backend="behavioral").run(x)
    assert g.outputs.shape == (4, 4)
    np.testing.assert_allclose(g.outputs, b.outputs, atol=1e-5)
    assert g.report()["network"]["energy_j"] > 0
    assert np.all(g.latency > 0)


def test_crossbar_lasana_smoke(xbar_net, crossbar_dataset):
    from repro.core.predictors import PredictorBank
    spec, x = xbar_net
    bank = PredictorBank("crossbar",
                         families=("mean", "linear")).fit(crossbar_dataset)
    run = NetworkEngine(spec, backend="lasana", surrogates=bank).run(x)
    assert np.all(np.isfinite(run.outputs))
    rep = run.report()
    assert rep["network"]["energy_j"] > 0
    # one row evaluation per segment per output per sample
    assert rep["layers"][0]["events"] == 4 * 8 * 2    # B * n_out * n_seg
    assert rep["layers"][1]["events"] == 4 * 4 * 1


# --- heterogeneous mixed-circuit graphs (ISSUE-2) -----------------------------

T_MIX, B_MIX = 25, 4


@pytest.fixture(scope="module")
def xbar_bank_q():
    """Quality crossbar bank (gbdt rides the physics-informed row-current
    feature; see circuits.CrossbarRow.surrogate_features)."""
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("crossbar",
                       TestbenchConfig(n_runs=150, n_steps=80, seed=2))
    return PredictorBank("crossbar",
                         families=("linear", "gbdt", "mlp")).fit(ds)


@pytest.fixture(scope="module")
def mixed_net():
    """Crossbar MAC front-end -> LIF bank with a recurrent inhibition
    self-edge, driven by time-varying ternary DAC patterns."""
    rng = np.random.default_rng(3)
    xw = rng.integers(-1, 2, (20, 8)).astype(np.float32)
    lw = (rng.normal(0, 0.5, (8, 6)) * 2.2).astype(np.float32)
    params = jnp.asarray([0.58, 0.5, 0.5, 0.5], jnp.float32)
    inhib = -0.6 * (1 - np.eye(6, dtype=np.float32))
    spec = graph_spec([crossbar_layer(xw), lif_layer(lw, params)],
                      edges=[recurrent_edge(1, 1, inhib)])
    seq = np.empty((T_MIX, B_MIX, 20), np.float32)
    cur = rng.integers(-1, 2, (B_MIX, 20)).astype(np.float32)
    for t in range(T_MIX):          # re-draw ~20% of the DAC lines per tick
        flip = rng.random((B_MIX, 20)) < 0.2
        cur = np.where(flip, rng.integers(-1, 2, (B_MIX, 20)), cur)
        seq[t] = cur * 0.8
    return spec, jnp.asarray(seq)


def test_mixed_crossbar_lif_parity(net_bank, xbar_bank_q, mixed_net):
    """Crossbar->LIF recurrent graph: all three backends run from ONE spec
    and LASANA standalone tracks behavioral spikes within the paper's 2%."""
    spec, seq = mixed_net
    banks = {"lif": net_bank, "crossbar": xbar_bank_q}
    gold = NetworkEngine(spec, backend="golden").run(seq)
    behav = NetworkEngine(spec, backend="behavioral").run(seq)
    las = NetworkEngine(spec, backend="lasana", surrogates=banks).run(seq)
    assert np.all(np.isfinite(gold.outputs))
    assert np.all(np.isfinite(las.outputs))
    # crossbar codes: surrogate tracks the behavioral DC solve closely
    code_err = np.abs(las.layer_spikes[0] - behav.layer_spikes[0])
    assert code_err.mean() < 0.1, code_err.mean()
    # LIF spikes: <2% mismatch across the spiking layer
    mism = np.mean((las.layer_spikes[1] > 0.75)
                   != (behav.layer_spikes[1] > 0.75))
    assert mism < 0.02, f"mixed-graph spike mismatch {mism:.4f}"
    # energy is attributed to every layer of a mixed graph
    rep = las.report()
    assert all(l["energy_j"] > 0 for l in rep["layers"])


def test_mixed_annotation_reproduces_behavioral(net_bank, xbar_bank_q,
                                                mixed_net):
    """Annotation mode on a mixed graph: exact behavioral outputs on every
    layer (codes AND spikes), energies filled in by LASANA."""
    spec, seq = mixed_net
    banks = {"lif": net_bank, "crossbar": xbar_bank_q}
    behav = NetworkEngine(spec, backend="behavioral").run(seq)
    annot = NetworkEngine(spec, backend="lasana", surrogates=banks,
                          mode="annotation").run(seq)
    for a, b in zip(annot.layer_spikes, behav.layer_spikes):
        np.testing.assert_array_equal(a, b)
    assert behav.energy.sum() == 0.0
    assert annot.energy.sum() > 0


def test_recurrent_edge_one_tick_delay():
    """A strong inhibitory self-loop must act exactly one tick late: the
    first spike is unaffected, the *next* tick is suppressed, and the
    deterministic behavioral trace alternates spike / silence."""
    w = jnp.asarray([[2.5]], jnp.float32)          # supra-threshold drive
    params = jnp.asarray([0.58, 0.5, 0.5, 0.5], jnp.float32)
    spikes = jnp.full((12, 1, 1), 1.5, jnp.float32)   # input spike every tick
    base_spec = graph_spec([lif_layer(w, params)])
    rec_spec = graph_spec([lif_layer(w, params)],
                          edges=[recurrent_edge(0, 0,
                                                jnp.asarray([[-10.0]]))])
    base = NetworkEngine(base_spec, backend="behavioral").run(spikes)
    rec = NetworkEngine(rec_spec, backend="behavioral").run(spikes)
    b = (base.out_spikes[:, 0, 0] > 0.75)
    r = (rec.out_spikes[:, 0, 0] > 0.75)
    assert b.all()                       # without the edge: fires every tick
    assert r[0] == b[0]                  # delayed edge can't touch tick 0
    assert not r[1]                      # ...but suppresses tick 1
    np.testing.assert_array_equal(r, np.arange(12) % 2 == 0)   # alternation


def test_adapter_shape_dtype_round_trips():
    """Every (src, dst) adapter preserves shape + float32 and lands in the
    destination's native range."""
    amp = 1.5
    spikes = jnp.asarray(np.random.default_rng(0)
                         .choice([0.0, amp], (3, 5)), jnp.float32)
    codes = jnp.asarray(np.random.default_rng(1)
                        .normal(0, 2.0, (3, 5)), jnp.float32)
    for y in (spikes, codes):
        for src, dst in (("lif", "lif"), ("lif", "crossbar"),
                         ("crossbar", "lif"), ("crossbar", "crossbar"),
                         ("input", "lif"), ("input", "crossbar")):
            u = adapt_signal(src, dst, y, spike_amp=amp)
            assert u.shape == y.shape
            assert u.dtype == jnp.float32
    # range contracts
    v = adapt_signal("lif", "crossbar", spikes, spike_amp=amp)
    assert float(jnp.abs(v).max()) <= 0.8 + 1e-6          # DAC rails
    u = adapt_signal("crossbar", "lif", codes, spike_amp=amp)
    assert float(jnp.abs(u).max()) <= amp + 1e-6          # rate-encoded amps
    x = adapt_signal("crossbar", "crossbar", codes, spike_amp=amp)
    assert float(jnp.abs(x).max()) <= 0.8 + 1e-6
    # "none" activation passes codes through linearly (scaled only)
    lin = adapt_signal("crossbar", "crossbar", codes, spike_amp=amp,
                       activation="none")
    np.testing.assert_allclose(np.asarray(lin), np.asarray(codes) * 0.8,
                               rtol=1e-6)
    with pytest.raises(ValueError, match="adapter"):
        adapt_signal("lif", "spice", spikes)
    # event discrimination: spikes at half-amplitude, analog at 5%
    assert event_threshold("lif", amp) == pytest.approx(0.75)
    assert event_threshold("crossbar", amp) == pytest.approx(0.075)


def test_report_attributes_circuit_kinds(mixed_net):
    spec, seq = mixed_net
    run = NetworkEngine(spec, backend="behavioral").run(seq)
    rep = run.report()
    assert [l["circuit"] for l in rep["layers"]] == ["crossbar", "lif"]
    assert all(l["backend"] == "behavioral" for l in rep["layers"])
    assert set(rep["by_circuit"]) == {"crossbar", "lif"}
    assert rep["by_circuit"]["lif"]["events"] == sum(
        l["events"] for l in rep["layers"] if l["circuit"] == "lif")


def test_edge_and_bank_validation(mixed_net):
    spec, _ = mixed_net
    # mixed graph with a single surrogate (not a mapping) is rejected
    with pytest.raises(ValueError, match="mixed-circuit"):
        NetworkEngine(spec, backend="lasana", surrogates=object())
    with pytest.raises(ValueError, match="missing a.*PredictorBank"):
        NetworkEngine(spec, backend="lasana", surrogates={"lif": object()})
    # edge shape validation: lif dst wants (n_out[src], n_out[dst])
    w = jnp.ones((4, 3), jnp.float32)
    p = jnp.asarray([0.58, 0.5, 0.5, 0.5], jnp.float32)
    bad = graph_spec([lif_layer(w, p)],
                     edges=[EdgeSpec(0, 0, jnp.ones((3, 2)))])
    with pytest.raises(ValueError, match="weight shape"):
        NetworkEngine(bad, backend="behavioral")
    oob = graph_spec([lif_layer(w, p)], edges=[EdgeSpec(0, 5, jnp.ones((3, 3)))])
    with pytest.raises(ValueError, match="out of range"):
        NetworkEngine(oob, backend="behavioral")


# --- integer event accounting (ISSUE-4) ---------------------------------------

def test_event_counts_are_exact_integers(net_bank, tiny_net):
    """ISSUE-4 regression: per-tick event counts used to accumulate as
    fp32, silently dropping whole events past 2^24 per tick/layer (dry-run
    scales reach 2^27 circuits). The counting primitive must be exact
    where fp32 demonstrably is not, and the run record must carry integer
    counts end-to-end."""
    from repro.core.network import _count_events
    n = 2 ** 24 + 3
    mask = jnp.ones((n,), bool)
    exact = int(_count_events(mask))
    assert exact == n
    # the old fp32 formulation loses the tail at exactly this scale
    fp32 = int(jnp.sum(mask.astype(jnp.float32)))
    assert fp32 != n
    spec, spikes = tiny_net
    run = NetworkEngine(spec, backend="lasana", surrogates=net_bank
                        ).run(spikes)
    assert np.issubdtype(run.events.dtype, np.integer)
    assert (run.events >= 0).all()
    assert run.report()["network"]["events"] == int(run.events.sum())
