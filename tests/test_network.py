"""Network-level event-driven engine (core/network.py).

Covers the ISSUE-1 acceptance properties: scheduler determinism under a
fixed seed, standalone-vs-annotation mode consistency, and network-level
LASANA-vs-behavioral spike-train parity within the paper tolerance (<2%
behavioral error) on a tiny 2-layer net — plus mesh batch-parallel parity
and report aggregation invariants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.network import (NetworkEngine, crossbar_mlp_spec, snn_spec)
from repro.core.simulate import run_snn_golden, run_snn_lasana

T_STEPS, BATCH = 40, 4


@pytest.fixture(scope="module")
def net_bank():
    """Quality LIF bank — large enough for <2% network-level parity."""
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("lif", TestbenchConfig(n_runs=600, n_steps=80, seed=1))
    return PredictorBank("lif", families=("linear", "mlp")).fit(ds)


@pytest.fixture(scope="module")
def tiny_net():
    """2-layer 12-8-4 LIF net + fixed-seed Poisson spike stimulus."""
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (12, 8)) * 0.8
    w2 = jax.random.normal(jax.random.PRNGKey(1), (8, 4)) * 0.8
    params = [jnp.asarray([0.58, 0.5, 0.5, 0.5])] * 2
    spec = snn_spec([w1, w2], params)
    spikes = (jax.random.bernoulli(jax.random.PRNGKey(2), 0.2,
                                   (T_STEPS, BATCH, 12)) * 1.5
              ).astype(jnp.float32)
    return spec, spikes


def test_scheduler_deterministic_under_fixed_seed(net_bank, tiny_net):
    """Same spec + same stimulus -> bit-identical runs, engine reuse or not."""
    spec, spikes = tiny_net
    eng = NetworkEngine(spec, backend="lasana", bank=net_bank)
    r1 = eng.run(spikes)
    r2 = eng.run(spikes)                                   # cached jit
    r3 = NetworkEngine(spec, backend="lasana", bank=net_bank).run(spikes)
    for other in (r2, r3):
        np.testing.assert_array_equal(r1.out_spikes, other.out_spikes)
        np.testing.assert_array_equal(r1.energy, other.energy)
        np.testing.assert_array_equal(r1.events, other.events)
        np.testing.assert_array_equal(r1.flush_energy, other.flush_energy)


def test_standalone_vs_annotation_consistency(net_bank, tiny_net):
    """Annotation mode must reproduce behavioral spikes EXACTLY (it only
    adds energy/latency) and its energy must land near standalone's."""
    spec, spikes = tiny_net
    behav = NetworkEngine(spec, backend="behavioral").run(spikes)
    annot = NetworkEngine(spec, backend="lasana", bank=net_bank,
                          mode="annotation").run(spikes)
    stand = NetworkEngine(spec, backend="lasana", bank=net_bank).run(spikes)
    np.testing.assert_array_equal(annot.out_spikes, behav.out_spikes)
    for a, b in zip(annot.layer_spikes, behav.layer_spikes):
        np.testing.assert_array_equal(a, b)
    # behavioral alone reports zero energy; annotation fills it in
    assert behav.energy.sum() == 0.0
    e_a = annot.energy.sum() + annot.flush_energy.sum()
    e_s = stand.energy.sum() + stand.flush_energy.sum()
    assert np.isfinite(e_a) and e_a > 0
    assert abs(e_a - e_s) / e_s < 0.5, (e_a, e_s)


def test_lasana_behavioral_spike_parity(net_bank, tiny_net):
    """Paper tolerance: <2% spike-train mismatch across the whole net."""
    spec, spikes = tiny_net
    behav = NetworkEngine(spec, backend="behavioral").run(spikes)
    las = NetworkEngine(spec, backend="lasana", bank=net_bank).run(spikes)
    mism = sum(np.sum((b > 0.75) != (l > 0.75)) for b, l in
               zip(behav.layer_spikes, las.layer_spikes))
    total = sum(b.size for b in behav.layer_spikes)
    assert mism / total < 0.02, f"spike mismatch {mism / total:.4f}"


def test_lasana_energy_tracks_golden(net_bank, tiny_net):
    """Event-driven totals (incl. idle flush) land near the golden sim."""
    spec, spikes = tiny_net
    gold = NetworkEngine(spec, backend="golden").run(spikes)
    las = NetworkEngine(spec, backend="lasana", bank=net_bank).run(spikes)
    e_g = gold.report()["network"]["energy_j"]
    e_l = las.report()["network"]["energy_j"]
    assert abs(e_l - e_g) / e_g < 0.15, (e_l, e_g)


def test_mesh_batch_parallel_parity(net_bank, tiny_net):
    """shard_map over a 1-device mesh must not change any output."""
    spec, spikes = tiny_net
    mesh = Mesh(np.array(jax.devices()[:1]), ("batch",))
    base = NetworkEngine(spec, backend="lasana", bank=net_bank).run(spikes)
    shard = NetworkEngine(spec, backend="lasana", bank=net_bank,
                          mesh=mesh).run(spikes)
    np.testing.assert_array_equal(base.out_spikes, shard.out_spikes)
    np.testing.assert_allclose(base.energy, shard.energy, rtol=1e-6)
    np.testing.assert_allclose(base.flush_energy, shard.flush_energy,
                               rtol=1e-6)
    np.testing.assert_array_equal(base.events, shard.events)


def test_report_aggregation(net_bank, tiny_net):
    """The network report must be consistent with the raw per-tick arrays."""
    spec, spikes = tiny_net
    run = NetworkEngine(spec, backend="lasana", bank=net_bank).run(spikes)
    rep = run.report()
    assert len(rep["layers"]) == spec.n_layers
    for i, layer in enumerate(rep["layers"]):
        np.testing.assert_allclose(
            layer["energy_j"],
            run.energy[:, i].sum() + run.flush_energy[i], rtol=1e-6)
        assert layer["events"] == int(run.events[:, i].sum())
    np.testing.assert_allclose(
        rep["network"]["energy_j"],
        sum(l["energy_j"] for l in rep["layers"]), rtol=1e-6)
    assert rep["network"]["events"] == int(run.events.sum())
    assert rep["network"]["ticks"] == T_STEPS
    # event-driven scheduling actually skips idle circuits
    assert rep["network"]["events"] < T_STEPS * BATCH * (8 + 4)


def test_golden_backend_matches_simulate_wrapper(tiny_net):
    """The compat wrapper in simulate.py is the engine under the hood."""
    spec, spikes = tiny_net
    run = NetworkEngine(spec, backend="golden").run(spikes)
    counts, energy = run_snn_golden(
        "lif", [l.weight for l in spec.layers],
        spikes, [l.params for l in spec.layers])
    np.testing.assert_array_equal(run.outputs, counts)
    np.testing.assert_allclose(run.energy.sum(), energy, rtol=1e-6)


def test_invalid_configuration_raises(tiny_net):
    spec, _ = tiny_net
    with pytest.raises(ValueError, match="backend"):
        NetworkEngine(spec, backend="spice")
    with pytest.raises(ValueError, match="PredictorBank"):
        NetworkEngine(spec, backend="lasana")
    with pytest.raises(ValueError, match="mode"):
        NetworkEngine(spec, backend="lasana", bank=object(), mode="oracle")


# --- crossbar (combinational) path -------------------------------------------

@pytest.fixture(scope="module")
def xbar_net():
    rng = np.random.default_rng(7)
    ws = [rng.integers(-1, 2, (40, 8)).astype(np.float32),
          rng.integers(-1, 2, (8, 4)).astype(np.float32)]
    x = rng.uniform(-0.8, 0.8, (4, 40)).astype(np.float32)
    return crossbar_mlp_spec(ws), x


def test_crossbar_golden_vs_behavioral(xbar_net):
    """Ideal settle + ADC quantization: behavioral must equal golden."""
    spec, x = xbar_net
    g = NetworkEngine(spec, backend="golden").run(x)
    b = NetworkEngine(spec, backend="behavioral").run(x)
    assert g.outputs.shape == (4, 4)
    np.testing.assert_allclose(g.outputs, b.outputs, atol=1e-5)
    assert g.report()["network"]["energy_j"] > 0
    assert np.all(g.latency > 0)


def test_crossbar_lasana_smoke(xbar_net, crossbar_dataset):
    from repro.core.predictors import PredictorBank
    spec, x = xbar_net
    bank = PredictorBank("crossbar",
                         families=("mean", "linear")).fit(crossbar_dataset)
    run = NetworkEngine(spec, backend="lasana", bank=bank).run(x)
    assert np.all(np.isfinite(run.outputs))
    rep = run.report()
    assert rep["network"]["energy_j"] > 0
    # one row evaluation per segment per output per sample
    assert rep["layers"][0]["events"] == 4 * 8 * 2    # B * n_out * n_seg
    assert rep["layers"][1]["events"] == 4 * 4 * 1
