"""Surrogate persistence (deployable-artifact contract).

Round-trip property: for EVERY model family, ``Surrogate.save`` ->
``load`` -> bit-identical ``predict`` on random feature batches. Plus the
format-version guard (a mismatched artifact must refuse to load, never be
reinterpreted) and the legacy ``persist.save_bank``/``load_bank`` shims.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # minimal CPU container
    from _hyp_fallback import given, settings, st

from repro.core.predictors import PREDICTOR_DEFS, PredictorBank, \
    build_features
from repro.core.surrogate import FORMAT_VERSION, Surrogate

# one predictor per family: a single surrogate covers the whole registry
FAMILY_PER_PREDICTOR = {
    "M_O": "mlp",
    "M_V": "linear",
    "M_ED": "gbdt",
    "M_ES": "table",
    "M_L": "mean",
}


@pytest.fixture(scope="module")
def all_family_surrogate(lif_dataset):
    """A surrogate whose five predictors span all five model families
    (small family configs — persistence cares about arrays, not MSE)."""
    from repro.core.models import (GBDTModel, LinearModel, MLPModel,
                                   MeanModel, TableModel)
    mk = {"mean": MeanModel, "linear": LinearModel,
          "table": lambda: TableModel(max_rows=500),
          "gbdt": lambda: GBDTModel(n_trees=6, max_depth=3),
          "mlp": lambda: MLPModel(hidden=(8,), max_epochs=2)}
    bank = PredictorBank("lif", families=())
    for pname, fam in FAMILY_PER_PREDICTOR.items():
        d = PREDICTOR_DEFS[pname]
        chain = d.get("chain_out", False)
        tr = lif_dataset.train.of_kind(*d["kinds"])
        va = lif_dataset.val.of_kind(*d["kinds"])
        xtr = bank.augment_features(
            build_features(tr, prev_out=d["prev_out"], chain_out=chain))
        xva = bank.augment_features(
            build_features(va, prev_out=d["prev_out"], chain_out=chain))
        ytr = (getattr(tr, d["target"]) * d["scale"]).astype(np.float32)
        yva = (getattr(va, d["target"]) * d["scale"]).astype(np.float32)
        model = mk[fam]()
        model.fit(xtr, ytr, xva, yva)
        bank.selected[pname] = model
    return Surrogate.from_bank(bank), bank


def _random_features(pname, seed, n=48):
    d = PREDICTOR_DEFS[pname]
    rng = np.random.default_rng(seed)
    # lif raw schema: 3 inputs + v + tau + 4 params (+ o_prev [+ o_new])
    dim = 3 + 1 + 1 + 4 + (1 if d["prev_out"] else 0) \
        + (1 if d.get("chain_out", False) else 0)
    return rng.normal(0.0, 1.0, (n, dim)).astype(np.float32)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_roundtrip_bit_identical_every_family(tmp_path_factory,
                                              all_family_surrogate, seed):
    """save -> load -> predict must be BIT-identical for every family."""
    sur, _ = all_family_surrogate
    assert dict(sur.manifest.families) == FAMILY_PER_PREDICTOR
    path = str(tmp_path_factory.mktemp("rt") / "sur.npz")
    sur.save(path)
    loaded = Surrogate.load(path)
    assert loaded.manifest == sur.manifest
    for pname in FAMILY_PER_PREDICTOR:
        x = jnp.asarray(_random_features(pname, seed))
        a = np.asarray(sur.predict(pname, x))
        b = np.asarray(loaded.predict(pname, x))
        np.testing.assert_array_equal(a, b, err_msg=pname)


def test_surrogate_matches_bank_predictions(all_family_surrogate):
    """The frozen artifact reproduces PredictorBank.predict exactly."""
    sur, bank = all_family_surrogate
    for pname in FAMILY_PER_PREDICTOR:
        x = jnp.asarray(_random_features(pname, seed=7))
        np.testing.assert_array_equal(np.asarray(bank.predict(pname, x)),
                                      np.asarray(sur.predict(pname, x)))


def test_format_version_mismatch_refuses_to_load(tmp_path,
                                                 all_family_surrogate):
    sur, _ = all_family_surrogate
    path = str(tmp_path / "sur.npz")
    sur.save(path)
    # rewrite the manifest with a future format version
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(bytes(arrays["__manifest__"].tobytes()).decode())
    meta["format_version"] = FORMAT_VERSION + 1
    arrays["__manifest__"] = np.frombuffer(json.dumps(meta).encode(),
                                           dtype=np.uint8)
    np.savez_compressed(path, **arrays)
    with pytest.raises(ValueError, match="format version"):
        Surrogate.load(path)
    # a non-artifact npz is rejected too
    other = str(tmp_path / "junk.npz")
    np.savez(other, a=np.zeros(3))
    with pytest.raises(ValueError, match="__manifest__"):
        Surrogate.load(other)


def test_fit_info_survives_roundtrip(tmp_path, lif_bank):
    sur = lif_bank.to_surrogate()
    assert sur.fit_info and "M_O" in sur.fit_info
    path = str(tmp_path / "bank.npz")
    sur.save(path)
    loaded = Surrogate.load(path)
    assert loaded.fit_info == json.loads(json.dumps(sur.fit_info))


def test_legacy_persist_shims(tmp_path, lif_bank_mlp, lif_dataset):
    """persist.save_bank/load_bank still round-trip (as Surrogates)."""
    from repro.core.persist import load_bank, save_bank
    path = str(tmp_path / "lif_bank.npz")
    with pytest.deprecated_call():
        save_bank(lif_bank_mlp, path)
    with pytest.deprecated_call():
        loaded = load_bank(path)
    assert isinstance(loaded, Surrogate)
    for pname, d in PREDICTOR_DEFS.items():
        te = lif_dataset.test.of_kind(*d["kinds"])
        if len(te) == 0:
            continue
        x = jnp.asarray(build_features(
            te, prev_out=d["prev_out"],
            chain_out=d.get("chain_out", False))[:64])
        np.testing.assert_allclose(
            np.asarray(lif_bank_mlp.predict(pname, x)),
            np.asarray(loaded.predict(pname, x)), rtol=1e-6, atol=1e-20)


def test_load_bank_reads_prefacade_format(tmp_path, lif_bank):
    """Artifacts written by the OLD save_bank (manifest {circuit,
    predictors}, no format_version) still load, migrated to a Surrogate."""
    from repro.core.models import LinearModel, MeanModel
    from repro.core.persist import load_bank
    # replicate the pre-facade on-disk format for the selected models
    manifest = {"circuit": lif_bank.circuit_name, "predictors": {}}
    arrays = {}
    for pname, m in lif_bank.selected.items():
        if isinstance(m, MeanModel):
            manifest["predictors"][pname] = {"family": "mean", "mu": m.mu}
        elif isinstance(m, LinearModel):
            manifest["predictors"][pname] = {"family": "linear"}
            arrays[f"{pname}/w"] = np.asarray(m.w)
            arrays[f"{pname}/mu"] = np.asarray(m.sx.mu)
            arrays[f"{pname}/sd"] = np.asarray(m.sx.sd)
        else:                                    # lif_bank is mean+linear
            raise AssertionError(type(m))
    arrays["__manifest__"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8)
    path = str(tmp_path / "legacy.npz")
    np.savez_compressed(path, **arrays)
    with pytest.deprecated_call():
        migrated = load_bank(path)
    assert isinstance(migrated, Surrogate)
    for pname in lif_bank.selected:
        x = jnp.asarray(_random_features(pname, seed=11))
        np.testing.assert_array_equal(
            np.asarray(lif_bank.predict(pname, x)),
            np.asarray(migrated.predict(pname, x)), err_msg=pname)


def test_loaded_surrogate_runs_algorithm1(tmp_path, lif_bank_mlp):
    import jax
    from repro.core.circuits import LIFNeuron
    from repro.core.wrapper import init_state, lasana_step
    path = str(tmp_path / "bank2.npz")
    lif_bank_mlp.to_surrogate().save(path)
    sur = Surrogate.load(path)
    circ = LIFNeuron()
    key = jax.random.PRNGKey(0)
    n = 16
    state = init_state(n, circ.sample_params(key, n))
    changed = jnp.ones((n,), bool)
    x = circ.sample_inputs(key, (n,))
    s, e, l, o = lasana_step(sur, state, changed, x, 5.0, 5.0, spiking=True)
    assert np.all(np.isfinite(np.asarray(e)))


def test_save_load_path_extension_normalized(tmp_path, lif_bank):
    """ISSUE-4 regression: ``save("foo")`` writes ``foo.npz`` (numpy
    appends the extension), so ``load("foo")`` used to fail. Both
    spellings now round-trip, through the class API and the facade."""
    import os

    import repro.lasana as lasana
    from repro.core.surrogate import SurrogateLibrary
    sur = lif_bank.to_surrogate()
    bare = str(tmp_path / "artifact")
    sur.save(bare)
    assert os.path.exists(bare + ".npz") and not os.path.exists(bare)
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(8, 9)).astype(np.float32))
    want = np.asarray(sur.predict("M_O", x))
    for spec in (bare, bare + ".npz"):
        loaded = Surrogate.load(spec)
        np.testing.assert_array_equal(want,
                                      np.asarray(loaded.predict("M_O", x)))
        np.testing.assert_array_equal(
            want, np.asarray(lasana.load(spec).predict("M_O", x)))
    # explicit-extension saves are untouched (no double extension)
    sur.save(str(tmp_path / "explicit.npz"))
    assert os.path.exists(tmp_path / "explicit.npz")
    assert not os.path.exists(tmp_path / "explicit.npz.npz")
    # the library round trip (directory of {kind}.npz) keeps working
    lib = SurrogateLibrary({"lif": sur})
    lib.save(str(tmp_path / "lib"))
    loaded_lib = lasana.load(str(tmp_path / "lib"))
    assert loaded_lib.kinds() == ("lif",)


def test_load_missing_file_names_both_tried_paths(tmp_path):
    """ISSUE-5 bugfix: a missing artifact used to surface as a raw
    ``np.load`` error naming only the post-normalization ``.npz`` path.
    Both tried spellings must appear in a clean FileNotFoundError."""
    bare = str(tmp_path / "nowhere")
    with pytest.raises(FileNotFoundError) as ei:
        Surrogate.load(bare)
    msg = str(ei.value)
    assert bare in msg and bare + ".npz" in msg
    # an explicit-extension path that does not exist: one spelling tried
    explicit = str(tmp_path / "gone.npz")
    with pytest.raises(FileNotFoundError) as ei:
        Surrogate.load(explicit)
    assert explicit in str(ei.value)
