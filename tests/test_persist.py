"""Bank persistence roundtrip (deployable-artifact contract)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.persist import load_bank, save_bank
from repro.core.predictors import PREDICTOR_DEFS, build_features


def test_bank_roundtrip(tmp_path, lif_bank_mlp, lif_dataset):
    path = str(tmp_path / "lif_bank.npz")
    save_bank(lif_bank_mlp, path)
    loaded = load_bank(path)
    for pname, d in PREDICTOR_DEFS.items():
        te = lif_dataset.test.of_kind(*d["kinds"])
        if len(te) == 0:
            continue
        x = jnp.asarray(build_features(
            te, prev_out=d["prev_out"],
            chain_out=d.get("chain_out", False))[:64])
        a = np.asarray(lif_bank_mlp.predict(pname, x))
        b = np.asarray(loaded.predict(pname, x))
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-20)


def test_loaded_bank_runs_algorithm1(tmp_path, lif_bank_mlp):
    import jax
    from repro.core.circuits import LIFNeuron
    from repro.core.wrapper import init_state, lasana_step
    path = str(tmp_path / "bank2.npz")
    save_bank(lif_bank_mlp, path)
    bank = load_bank(path)
    circ = LIFNeuron()
    key = jax.random.PRNGKey(0)
    n = 16
    state = init_state(n, circ.sample_params(key, n))
    changed = jnp.ones((n,), bool)
    x = circ.sample_inputs(key, (n,))
    s, e, l, o = lasana_step(bank, state, changed, x, 5.0, 5.0, spiking=True)
    assert np.all(np.isfinite(np.asarray(e)))
