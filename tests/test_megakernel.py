"""Differential parity + property-test harness for the whole-tick megakernel.

Three generations of the LASANA tick body must agree on every graph the
engine accepts:

  percall  the PR 3 per-``predict``-call formulation (``fused=False``)
  fused    the PR 5 stacked-dispatch path (``fused_kernel=False``)
  mega     the whole-tick megakernel (``fused_kernel=True``) — head packs
           VMEM-resident, idle -> act -> transition chained in one body,
           time-looped over whole chunks where eligible

Contract: discrete records (outputs, spike trains, event counts, t_last)
are BIT-identical across all three; continuous heads (energy/latency)
agree to rtol 1e-5 on trained surrogates (packing only reorders float
reductions). The property tests sweep randomly generated ``NetworkSpec``s
— mixed lif|crossbar kinds, recurrent edges, ragged non-block-multiple
sizes, annotation mode, T % chunk != 0 — through all three engines; the
``lif_chunk`` sweep pins the time-looped golden kernel bit-for-bit to
chained ``LIFNeuron.step``.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from _hyp_fallback import given, settings, st

import jax
import jax.numpy as jnp

RTOL = 1e-5
ATOL = 1e-7


@pytest.fixture(scope="module")
def crossbar_bank(crossbar_dataset):
    from repro.core.predictors import PredictorBank
    return PredictorBank("crossbar",
                         families=("mean", "linear")).fit(crossbar_dataset)


_KIND = {"l": "lif", "x": "crossbar"}


def _libraries(lif_bank, crossbar_bank, topo):
    banks = {"lif": lif_bank, "crossbar": crossbar_bank}
    return {_KIND[c]: banks[_KIND[c]] for c in set(topo)}


def _rand_spec(seed: int, topo: str, recurrent: bool):
    """A ragged mixed-kind NetworkSpec from a topology string ('l'=lif,
    'x'=crossbar); sizes are deliberately NOT multiples of any block."""
    from repro.core.network import (crossbar_layer, graph_spec, lif_layer,
                                    recurrent_edge)
    rng = np.random.default_rng(seed)
    sizes = [int(rng.integers(3, 14)) for _ in range(len(topo) + 1)]
    layers = []
    for i, kind in enumerate(topo):
        w = (rng.normal(0, 0.5, (sizes[i], sizes[i + 1])) * 2.2
             ).astype(np.float32)
        if kind == "l":
            params = np.array([0.58, 0.5, 0.5, 0.5], np.float32)
            layers.append(lif_layer(w, params))
        else:
            layers.append(crossbar_layer(
                np.clip(np.round(w), -1, 1).astype(np.float32)))
    edges = []
    if recurrent:
        lifs = [i for i, k in enumerate(topo) if k == "l"]
        if lifs:
            i = lifs[-1]
            n = sizes[i + 1]
            inhib = (-0.5 * (1 - np.eye(n))).astype(np.float32)
            edges.append(recurrent_edge(i, i, inhib))
    return graph_spec(layers, edges=edges), sizes[0], topo[0]


def _stimulus(seed: int, t_steps: int, batch: int, fan_in: int,
              first_kind: str):
    rng = np.random.default_rng(seed + 1)
    if first_kind == "l":
        return ((rng.random((t_steps, batch, fan_in)) < 0.35)
                .astype(np.float32) * 1.5)
    return (rng.integers(-1, 2, (t_steps, batch, fan_in)) * 0.8
            ).astype(np.float32)


def _run_three(spec, x, banks, mode="standalone", record_hidden=True):
    from repro.core.network import NetworkEngine
    runs = {}
    for name, kw in (("mega", dict(fused_kernel=True)),
                     ("fused", dict(fused_kernel=False)),
                     ("percall", dict(fused=False))):
        eng = NetworkEngine(spec, surrogates=banks, mode=mode,
                            record_hidden=record_hidden, **kw)
        runs[name] = eng.run(x)
    return runs


def _assert_parity(runs):
    ref = runs["fused"]
    for name in ("mega", "percall"):
        r = runs[name]
        np.testing.assert_array_equal(r.outputs, ref.outputs, err_msg=name)
        if ref.out_spikes is not None:
            np.testing.assert_array_equal(r.out_spikes, ref.out_spikes,
                                          err_msg=name)
        if ref.layer_spikes is not None:
            for h, h0 in zip(r.layer_spikes, ref.layer_spikes):
                np.testing.assert_array_equal(h, h0, err_msg=name)
        np.testing.assert_array_equal(r.events, ref.events, err_msg=name)
        np.testing.assert_allclose(r.energy, ref.energy, rtol=RTOL,
                                   atol=ATOL, err_msg=name)
        np.testing.assert_allclose(r.latency, ref.latency, rtol=RTOL,
                                   atol=1e-4, err_msg=name)
        np.testing.assert_allclose(r.flush_energy, ref.flush_energy,
                                   rtol=RTOL, atol=ATOL, err_msg=name)


# --- the property sweep -----------------------------------------------------


@settings(max_examples=6)
@given(st.integers(min_value=0, max_value=10**6),
       st.sampled_from(["l", "ll", "xl", "lx", "xll"]),
       st.booleans())
def test_random_specs_three_way_parity(lif_bank, crossbar_bank, seed, topo,
                                       recurrent):
    """Random ragged mixed graphs: mega == fused == percall (discrete
    bitwise, continuous rtol 1e-5) on trained surrogates."""
    spec, fan_in, first = _rand_spec(seed, topo, recurrent)
    x = _stimulus(seed, 9, 2, fan_in, first)
    _assert_parity(_run_three(spec, x,
                              _libraries(lif_bank, crossbar_bank, topo)))


@settings(max_examples=3)
@given(st.integers(min_value=0, max_value=10**6),
       st.sampled_from(["ll", "xl"]))
def test_random_specs_annotation_parity(lif_bank, crossbar_bank, seed,
                                        topo):
    """Annotation mode: the behavioral model owns outputs/state, so ALL
    discrete records (including spike trains) are bitwise across paths and
    LASANA's energy/latency annotations agree to rtol 1e-5."""
    spec, fan_in, first = _rand_spec(seed, topo, False)
    x = _stimulus(seed, 7, 2, fan_in, first)
    _assert_parity(_run_three(
        spec, x, _libraries(lif_bank, crossbar_bank, topo),
        mode="annotation"))


def test_mega_streaming_bit_identical(lif_bank, crossbar_bank):
    """Chunked streaming on the mega engine == monolithic, bitwise, for
    T % chunk != 0 (the time-looped kernel must be chunk-size invariant)."""
    from repro.core.network import NetworkEngine
    spec, fan_in, first = _rand_spec(5, "xl", True)
    x = _stimulus(5, 23, 2, fan_in, first)
    eng = NetworkEngine(spec,
                        surrogates=_libraries(lif_bank, crossbar_bank, "xl"),
                        fused_kernel=True)
    mono = eng.run(x)
    for chunk in (1, 7, 23):
        s = eng.run_stream(x, chunk_ticks=chunk)
        np.testing.assert_array_equal(s.outputs, mono.outputs)
        np.testing.assert_array_equal(s.out_spikes, mono.out_spikes)
        np.testing.assert_array_equal(s.energy, mono.energy)
        np.testing.assert_array_equal(s.events, mono.events)
        np.testing.assert_array_equal(s.flush_energy, mono.flush_energy)


def test_chunk_fast_path_matches_generic_scan(lif_bank):
    """Single-LIF-layer graphs take the time-looped fast path; a recurrent
    edge makes the same graph ineligible and falls back to the generic
    scan — the two must agree with each other and with stacked dispatch."""
    from repro.core.network import NetworkEngine, graph_spec, lif_layer, \
        recurrent_edge
    rng = np.random.default_rng(7)
    w = (rng.normal(0, 0.5, (11, 6)) * 2.2).astype(np.float32)
    params = np.array([0.58, 0.5, 0.5, 0.5], np.float32)
    spec = graph_spec([lif_layer(w, params)])
    x = ((rng.random((17, 3, 11)) < 0.35).astype(np.float32) * 1.5)

    eng_m = NetworkEngine(spec, surrogates=lif_bank, fused_kernel=True)
    assert eng_m._chunk_eligible()
    runs = _run_three(spec, x, {"lif": lif_bank})
    _assert_parity(runs)

    zero = np.zeros((6, 6), np.float32)
    spec_r = graph_spec([lif_layer(w, params)],
                        edges=[recurrent_edge(0, 0, zero)])
    eng_r = NetworkEngine(spec_r, surrogates=lif_bank, fused_kernel=True)
    assert not eng_r._chunk_eligible()
    r = eng_r.run(x)                      # zero edge: same math, generic scan
    np.testing.assert_array_equal(r.outputs, runs["mega"].outputs)
    np.testing.assert_array_equal(r.out_spikes, runs["mega"].out_spikes)
    np.testing.assert_array_equal(r.energy, runs["mega"].energy)


def test_mega_shard_map_parity(lif_bank, crossbar_bank):
    """The megakernel body runs shard-local under shard_map; a 1-device
    mesh must reproduce the unsharded run exactly."""
    from jax.sharding import Mesh
    from repro.core.network import NetworkEngine
    spec, fan_in, first = _rand_spec(11, "xl", False)
    x = _stimulus(11, 8, 2, fan_in, first)
    banks = _libraries(lif_bank, crossbar_bank, "xl")
    mesh = Mesh(np.array(jax.devices()[:1]), ("batch",))
    r_plain = NetworkEngine(spec, surrogates=banks, fused_kernel=True
                            ).run(x)
    r_mesh = NetworkEngine(spec, surrogates=banks, fused_kernel=True,
                           mesh=mesh).run(x)
    np.testing.assert_array_equal(r_mesh.outputs, r_plain.outputs)
    np.testing.assert_array_equal(r_mesh.events, r_plain.events)
    np.testing.assert_allclose(r_mesh.energy, r_plain.energy, rtol=RTOL,
                               atol=ATOL)


def test_program_key_separates_megakernel(lif_bank, monkeypatch):
    """Flipping the fused-kernel switch (kwarg OR env) or the megakernel
    launcher must change the compiled-program cache key."""
    from repro.core.network import NetworkEngine, snn_spec
    w = np.eye(4, dtype=np.float32)
    spec = snn_spec([w], [np.array([0.58, 0.5, 0.5, 0.5], np.float32)])
    monkeypatch.delenv("REPRO_FUSED_KERNEL", raising=False)
    monkeypatch.delenv("REPRO_TICK_PALLAS", raising=False)
    banks = {"lif": lif_bank}

    def key(**kw):
        eng = NetworkEngine(spec, surrogates=banks, **kw)
        return eng._program_key("mono", 2, 9, eng._runtime_banks(None))

    base = key()
    assert key(fused_kernel=True) != base
    assert key(fused_kernel=False) == base         # env off == explicit off
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    assert key() == key(fused_kernel=True)
    assert key(fused_kernel=False) == base
    monkeypatch.setenv("REPRO_TICK_PALLAS", "1")
    k_pallas = key(fused_kernel=True)          # launcher joins the key
    monkeypatch.setenv("REPRO_TICK_PALLAS", "0")
    assert key(fused_kernel=True) != k_pallas


# --- dispatch helper: env and kwarg must agree ------------------------------


def test_fused_kernel_enabled_env_vs_kwarg(monkeypatch):
    """Satellite contract: kernels.ops is the ONE resolution point for the
    REPRO_FUSED_KERNEL / REPRO_TICK_PALLAS knobs — an explicit kwarg always
    wins, env only fills the None case."""
    from repro.kernels import ops
    for env, expect in ((None, False), ("0", False), ("1", True)):
        if env is None:
            monkeypatch.delenv("REPRO_FUSED_KERNEL", raising=False)
        else:
            monkeypatch.setenv("REPRO_FUSED_KERNEL", env)
        assert ops.fused_kernel_enabled() is expect
        assert ops.fused_kernel_enabled(True) is True
        assert ops.fused_kernel_enabled(False) is False

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    for env, expect in ((None, False), ("0", False), ("1", True)):
        if env is None:
            monkeypatch.delenv("REPRO_TICK_PALLAS", raising=False)
        else:
            monkeypatch.setenv("REPRO_TICK_PALLAS", env)
        assert ops.tick_pallas_enabled() is expect
        assert ops.tick_pallas_enabled(True) is True
        assert ops.tick_pallas_enabled(False) is False
    # platform default: hardware (interpret off) runs the Pallas launcher
    monkeypatch.delenv("REPRO_TICK_PALLAS", raising=False)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.tick_pallas_enabled() is True


def test_surrogate_predict_heads_kwarg_matches_env(lif_bank, monkeypatch):
    """predict_heads(fused_kernel=...) == flipping REPRO_FUSED_KERNEL."""
    sur = lif_bank.to_surrogate()
    rng = np.random.default_rng(0)
    feats = rng.uniform(0, 1, (13, 9)).astype(np.float32)
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    by_env = sur.predict_heads(feats_act=feats)
    monkeypatch.delenv("REPRO_FUSED_KERNEL")
    by_kwarg = sur.predict_heads(feats_act=feats, fused_kernel=True)
    for v, heads in by_env.items():
        for p, y in heads.items():
            np.testing.assert_array_equal(np.asarray(y),
                                          np.asarray(by_kwarg[v][p]))


# --- per-tick megakernel: Pallas launcher vs jnp body -----------------------


def _tick_inputs(sur, n, seed):
    from repro.core.wrapper import init_state
    rng = np.random.default_rng(seed)
    circ_n_in = 3 if sur.circuit == "lif" else 32
    n_p = 4 if sur.circuit == "lif" else 33
    state = init_state(n, jnp.asarray(
        rng.uniform(0.3, 0.7, (n, n_p)).astype(np.float32)))
    state = state._replace(
        v=jnp.asarray(rng.uniform(0, 1, n).astype(np.float32)),
        t_last=jnp.asarray(
            rng.choice([0.0, 5.0, 25.0], n).astype(np.float32)))
    changed = jnp.asarray(rng.random(n) < 0.6)
    x = jnp.asarray(rng.uniform(-1, 1, (n, circ_n_in)).astype(np.float32))
    return state, changed, x


@pytest.mark.parametrize("n", [5, 256, 300])
def test_network_tick_pallas_matches_jnp(lif_bank, n):
    """ONE pallas_call (interpret mode on CPU) == the jnp tick body:
    discrete records bitwise, continuous heads to rtol 1e-5, at ragged and
    block-multiple N."""
    from repro.kernels import tick_megakernel as mk
    sur = lif_bank.to_surrogate()
    pack, layout = mk.pack_heads(sur)
    assert pack is not None
    state, changed, x = _tick_inputs(sur, n, seed=n)
    t = jnp.float32(30.0)
    outs = {}
    for name, pallas in (("jnp", False), ("pallas", True)):
        ns, e, l, o = mk.megakernel_step(
            pack, "lif", state, changed, x, t, 5.0, spiking=True,
            vdd=1.5, layout=layout, pallas=pallas)
        outs[name] = (ns, e, l, o)
    ns_j, e_j, l_j, o_j = outs["jnp"]
    ns_p, e_p, l_p, o_p = outs["pallas"]
    np.testing.assert_array_equal(np.asarray(o_p), np.asarray(o_j))
    np.testing.assert_array_equal(np.asarray(ns_p.o), np.asarray(ns_j.o))
    np.testing.assert_array_equal(np.asarray(ns_p.t_last),
                                  np.asarray(ns_j.t_last))
    np.testing.assert_allclose(np.asarray(ns_p.v), np.asarray(ns_j.v),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(e_p), np.asarray(e_j),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_j),
                               rtol=RTOL, atol=1e-4)


@pytest.mark.parametrize("t_steps", [1, 4, 9])
def test_network_tick_chunk_pallas_matches_jnp(lif_bank, t_steps):
    """The time-looped chunk kernel == scanning the per-tick body: discrete
    records bitwise across every chunk length."""
    from repro.kernels import tick_megakernel as mk
    sur = lif_bank.to_surrogate()
    pack, layout = mk.pack_heads(sur)
    rng = np.random.default_rng(t_steps)
    n = 7
    state, _, _ = _tick_inputs(sur, n, seed=t_steps)
    changed_seq = jnp.asarray(rng.random((t_steps, n)) < 0.6)
    x_seq = jnp.asarray(
        rng.uniform(-1, 1, (t_steps, n, 3)).astype(np.float32))
    t_seq = (jnp.arange(t_steps, dtype=jnp.float32) + 1.0) * 5.0
    outs = {}
    for name, pallas in (("jnp", False), ("pallas", True)):
        ns, o_seq, e_seq, l_seq = mk.megakernel_chunk(
            pack, "lif", state, changed_seq, x_seq, t_seq, 5.0,
            spiking=True, vdd=1.5, layout=layout, pallas=pallas)
        outs[name] = (ns, o_seq, e_seq, l_seq)
    ns_j, o_j, e_j, l_j = outs["jnp"]
    ns_p, o_p, e_p, l_p = outs["pallas"]
    np.testing.assert_array_equal(np.asarray(o_p), np.asarray(o_j))
    np.testing.assert_array_equal(np.asarray(ns_p.o), np.asarray(ns_j.o))
    np.testing.assert_array_equal(np.asarray(ns_p.t_last),
                                  np.asarray(ns_j.t_last))
    np.testing.assert_allclose(np.asarray(e_p), np.asarray(e_j),
                               rtol=RTOL, atol=ATOL)


def test_megakernel_chunk_equals_step_loop(lif_bank):
    """jnp chunk == a python loop of per-tick steps, BITWISE — the scan
    formulation cannot drift from the tick it scans."""
    from repro.kernels import tick_megakernel as mk
    sur = lif_bank.to_surrogate()
    pack, layout = mk.pack_heads(sur)
    rng = np.random.default_rng(3)
    n, t_steps = 9, 6
    state, _, _ = _tick_inputs(sur, n, seed=3)
    changed_seq = jnp.asarray(rng.random((t_steps, n)) < 0.6)
    x_seq = jnp.asarray(
        rng.uniform(-1, 1, (t_steps, n, 3)).astype(np.float32))
    t_seq = (jnp.arange(t_steps, dtype=jnp.float32) + 1.0) * 5.0
    ns_c, o_c, e_c, l_c = mk.megakernel_chunk(
        pack, "lif", state, changed_seq, x_seq, t_seq, 5.0, spiking=True,
        vdd=1.5, layout=layout, pallas=False)
    st = state
    os_, es_, ls_ = [], [], []
    for ti in range(t_steps):
        st, e, l, o = mk.megakernel_step(
            pack, "lif", st, changed_seq[ti], x_seq[ti], t_seq[ti], 5.0,
            spiking=True, vdd=1.5, layout=layout, pallas=False)
        os_.append(o), es_.append(e), ls_.append(l)
    np.testing.assert_array_equal(np.asarray(o_c), np.stack(os_))
    np.testing.assert_array_equal(np.asarray(e_c), np.stack(es_))
    np.testing.assert_array_equal(np.asarray(l_c), np.stack(ls_))
    np.testing.assert_array_equal(np.asarray(ns_c.v), np.asarray(st.v))
    np.testing.assert_array_equal(np.asarray(ns_c.t_last),
                                  np.asarray(st.t_last))


# --- the time-looped golden LIF kernel --------------------------------------


@pytest.mark.parametrize("t_steps", [1, 3, 17])
@pytest.mark.parametrize("n", [5, 256])
def test_lif_chunk_bitwise_matches_circuit_step(t_steps, n):
    """ops.lif_chunk == T chained jitted ``LIFNeuron.step`` calls,
    bit-for-bit in fp32 — state, outputs, energy, latency, spike flags —
    across chunk lengths and ragged N."""
    from repro.core.circuits import LIFNeuron
    from repro.kernels import ops
    circ = LIFNeuron()
    rng = np.random.default_rng(n * 31 + t_steps)
    state = jnp.asarray(rng.uniform(0, 1, (n, 3)).astype(np.float32))
    x_seq = jnp.asarray(
        rng.uniform(0, 1.2, (t_steps, n, 3)).astype(np.float32))
    params = jnp.asarray(rng.uniform(0, 1, (n, 4)).astype(np.float32))
    new_state, obs = ops.lif_chunk(state, x_seq, params)
    step = jax.jit(circ.step)
    st, refs = state, []
    for t in range(t_steps):
        st, ob = step(st, x_seq[t], params)
        refs.append(ob)
    np.testing.assert_array_equal(np.asarray(new_state), np.asarray(st))
    for k in ("output", "energy", "latency", "spiked"):
        ref = np.stack([np.asarray(o[k]) for o in refs])
        np.testing.assert_array_equal(np.asarray(obs[k]), ref, err_msg=k)
