import numpy as np
import pytest

# NOTE: tests must see the real single CPU device — never set
# xla_force_host_platform_device_count here (multi-device tests use
# subprocesses; see test_ft.py / test_distributed.py).


@pytest.fixture(scope="session")
def lif_dataset():
    from repro.core.dataset import TestbenchConfig, build_dataset
    return build_dataset("lif", TestbenchConfig(n_runs=150, n_steps=80, seed=1))


@pytest.fixture(scope="session")
def crossbar_dataset():
    from repro.core.dataset import TestbenchConfig, build_dataset
    return build_dataset("crossbar",
                         TestbenchConfig(n_runs=80, n_steps=80, seed=2))


@pytest.fixture(scope="session")
def lif_bank(lif_dataset):
    """Cheap bank (mean+linear) — enough for wrapper-semantics tests."""
    from repro.core.predictors import PredictorBank
    return PredictorBank("lif", families=("mean", "linear")).fit(lif_dataset)


@pytest.fixture(scope="session")
def lif_bank_mlp(lif_dataset):
    """Quality bank for accuracy-threshold tests."""
    from repro.core.predictors import PredictorBank
    return PredictorBank("lif", families=("linear", "mlp")).fit(lif_dataset)
