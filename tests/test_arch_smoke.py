"""Per-architecture smoke tests: reduced same-family config, one train step
on CPU (finite loss, correct shapes), and prefill+decode == full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models.model import Model
from repro.optim import AdamW, AdamWConfig
from repro.train.step import init_train_state, make_train_step


def _batch(cfg, key, b, s, with_labels=True):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.n_frontend_tokens:
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    opt = AdamW(AdamWConfig(lr=0.05, warmup_steps=2, total_steps=10))
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, opt, key)
    batch = _batch(cfg, key, 2, 32)
    step = jax.jit(make_train_step(model, opt))
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state["step"]) == 1
    # params actually moved (fp32 compare; lr chosen above bf16 ULP)
    moved = 0.0
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(new_state["params"])):
        moved += float(np.sum(np.abs(np.asarray(a, np.float32)
                                     - np.asarray(b, np.float32))))
    assert moved > 1e-3, moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    b, s = 2, 16
    toks = jax.random.randint(key, (b, s + 2), 0, cfg.vocab)
    batch = _batch(cfg, key, b, s, with_labels=False)
    batch["tokens"] = toks[:, :s]
    _, cache = jax.jit(lambda p, bt: model.prefill(p, bt, max_seq=s + 4))(
        params, batch)
    dec = jax.jit(model.decode)
    _, cache = dec(params, cache, toks[:, s : s + 1])
    logits, cache = dec(params, cache, toks[:, s + 1 : s + 2])
    batch_full = dict(batch)
    batch_full["tokens"] = toks
    h, _ = jax.jit(lambda p, bt: model.forward(p, bt))(params, batch_full)
    from repro.models.layers import unembed
    want = unembed(params["embed"], h[:, -1:], cfg)
    got = np.asarray(logits, np.float32)
    want = np.asarray(want, np.float32)
    rel = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)
    assert rel < 0.15, f"{arch}: decode/forward mismatch rel={rel}"


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-2b"])
def test_subquadratic_cache_is_bounded(arch):
    """long_500k feasibility: cache size must not scale with context."""
    cfg = reduced_config(arch)
    model = Model(cfg)
    small = model.cache_specs(2, 1024)
    large = model.cache_specs(2, 1024 * 64)
    def nbytes(tree):
        return sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree.leaves(tree)
                   if hasattr(s, "shape") and s.shape)
    ratio = nbytes(large) / nbytes(small)
    assert ratio < 2.0, f"{arch} cache grew {ratio}x with 64x context"


def test_full_configs_param_counts():
    """Full configs match published sizes within 15%."""
    expected = {"starcoder2-3b": 3.0e9, "granite-3-8b": 8.1e9,
                "deepseek-67b": 67e9, "mistral-large-123b": 123e9,
                "deepseek-v3-671b": 671e9, "deepseek-moe-16b": 16.4e9,
                "whisper-base": 0.074e9, "pixtral-12b": 12e9,
                "mamba2-1.3b": 1.3e9, "recurrentgemma-2b": 2.7e9}
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.45, (arch, got, want)
