"""LASANA-as-a-service (ISSUE-8 tentpole): multi-tenant serving parity.

Acceptance properties:

  * continuous-batching parity — every multiplexed request's merged
    record matches a solo ``lasana.simulate`` of the same stimulus:
    bitwise on discrete records (outputs, spike traces, event counts),
    rtol 1e-5 on f32 energy sums (slot-wise reduction reassociates
    float addition) and on latency maxes, which additionally carry a
    one-ULP absolute epsilon from vectorization-width variance in the
    surrogate dots — nothing else differs — including
    mid-stream join/leave, heterogeneous lengths/batches, mixed
    recurrent graphs, annotation mode, and surrogate hot-swap;
  * compiled-program discipline: programs are bounded by shape buckets,
    never by request count or surrogate versions (two versions share one
    compiled slot program, compile_count == bucket count);
  * admission control: round-robin tenant fairness (no starvation),
    bounded-queue backpressure (``ServerBusy``), oversize rejection;
  * fault isolation + lane lifecycle: per-request errors (bad mode,
    engine-rejected surrogates, exploding on_chunk callbacks) fail only
    their own handle; idle lanes retire (bounded lane table, surrogate
    reference dropped with the key) and re-create compile-free;
  * store semantics (immutable versions, latest-resolve, pinned refs)
    and the JSON-lines wire protocol end to end.
"""

import io
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.lasana as lasana
from repro.core.network import (crossbar_layer, graph_spec, lif_layer,
                                recurrent_edge, snn_spec)
from repro.serve import (ArtifactStore, BucketPolicy, ServeConfig,
                         ServerBusy, SimServer, run_stdio, spec_content_key)
from repro.serve.store import parse_ref

CHUNK = 8
PARAMS = [0.58, 0.5, 0.5, 0.5]


def _make_spec(seed=0):
    k1, k2 = jax.random.PRNGKey(seed), jax.random.PRNGKey(seed + 100)
    w1 = jax.random.normal(k1, (12, 8)) * 0.8
    w2 = jax.random.normal(k2, (8, 4)) * 0.8
    return snn_spec([w1, w2], [jnp.asarray(PARAMS)] * 2)


def _stim(rng, t, b, n_in=12, rate=0.2, amp=1.5):
    return (rng.random((t, b, n_in)) < rate).astype(np.float32) * amp


def _assert_request_parity(solo, served, *, hidden=False):
    """Solo-vs-served record equivalence (see module docstring)."""
    np.testing.assert_array_equal(solo.outputs, served.outputs)
    np.testing.assert_array_equal(solo.events, served.events)
    if solo.out_spikes is not None:
        np.testing.assert_array_equal(solo.out_spikes, served.out_spikes)
    if hidden and solo.layer_spikes is not None:
        for a, b in zip(solo.layer_spikes, served.layer_spikes):
            np.testing.assert_array_equal(a, b)
    np.testing.assert_allclose(solo.energy, served.energy, rtol=1e-5,
                               atol=0)
    np.testing.assert_allclose(solo.latency, served.latency, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(solo.flush_energy, served.flush_energy,
                               rtol=1e-5, atol=0)


@pytest.fixture(scope="module")
def lif_surrogate(lif_bank):
    return lif_bank.to_surrogate()


@pytest.fixture(scope="module")
def shared_spec():
    """One spec shared by most tests so its facade engine (and compiled
    slot programs) are built once for the whole module."""
    return _make_spec(0)


@pytest.fixture(scope="module")
def two_versions(lif_dataset):
    """Two equal-structure artifacts (different seeds, same families):
    hot-swappable through one compiled program."""
    cfg = lambda seed: lasana.TrainConfig(n_runs=50, n_steps=40, seed=seed,
                                          families=("linear",))
    return lasana.train("lif", cfg(1)), lasana.train("lif", cfg(2))


# --- parity -------------------------------------------------------------------

def test_single_request_matches_simulate(lif_surrogate, shared_spec):
    """One request through the server IS a solo simulate — including
    hidden spike traces — and streams ceil(T/chunk) partial records."""
    rng = np.random.default_rng(0)
    x = _stim(rng, 20, 2)
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                record_hidden=True))
    seen = []
    h = srv.submit(shared_spec, x, surrogates=lif_surrogate,
                   on_chunk=seen.append)
    assert not h.done
    srv.run_until_idle()
    assert h.done and len(h.chunks()) == math.ceil(20 / CHUNK) == len(seen)
    solo = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                           record_hidden=True)
    _assert_request_parity(solo, h.result(), hidden=True)


def test_multiplexed_join_leave_parity(lif_surrogate, shared_spec):
    """The tentpole property: 7 concurrent requests of heterogeneous
    length/batch multiplexed onto 4 slots — later requests join
    mid-stream as earlier ones leave — and every merged record matches
    its solo run."""
    rng = np.random.default_rng(1)
    jobs = [(24, 2), (9, 1), (5, 1), (16, 2), (24, 1), (9, 1), (16, 1)]
    stims = [_stim(rng, t, b) for t, b in jobs]
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    handles = [srv.submit(shared_spec, x, surrogates=lif_surrogate,
                          tenant=f"t{i % 3}")
               for i, x in enumerate(stims)]
    srv.run_until_idle()
    stats = srv.stats()
    assert stats["requests_completed"] == len(jobs)
    assert stats["batch_occupancy"] > 0.3        # slots actually shared
    for (t, _b), x, h in zip(jobs, stims, handles):
        assert len(h.chunks()) == math.ceil(t / CHUNK)
        solo = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                               record_hidden=False)
        _assert_request_parity(solo, h.result())


def test_versions_share_compiled_programs(two_versions, lif_surrogate):
    """Hot-swap acceptance: two registered versions (one registered
    MID-workload) serve from separate lanes but ONE compiled slot
    program — compile_count == bucket count == 1 — and each request's
    record matches a solo run with the exact version it resolved."""
    s1, s2 = two_versions
    spec = _make_spec(7)                 # fresh spec => clean engine
    rng = np.random.default_rng(2)
    stims = [_stim(rng, 16, 1) for _ in range(4)]
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    assert srv.register_surrogate("lif", s1) == 1
    h_pin = srv.submit(spec, stims[0], surrogates="lif@1")
    h_old = srv.submit(spec, stims[1], surrogates="lif")     # latest = 1
    srv.run_until_idle()
    assert srv.register_surrogate("lif", s2) == 2            # hot-swap
    h_new = srv.submit(spec, stims[2], surrogates="lif")     # latest = 2
    h_pin2 = srv.submit(spec, stims[3], surrogates="lif@1")  # pinned old
    srv.run_until_idle()
    assert srv.compile_count() == 1
    assert srv.stats()["n_lanes"] == 2
    assert h_pin.surrogate_ref == h_old.surrogate_ref == ("lif", 1)
    assert h_new.surrogate_ref == ("lif", 2)
    assert h_pin2.surrogate_ref == ("lif", 1)
    for h, x, s in [(h_pin, stims[0], s1), (h_old, stims[1], s1),
                    (h_new, stims[2], s2), (h_pin2, stims[3], s1)]:
        _assert_request_parity(
            lasana.simulate(spec, x, surrogates=s, record_hidden=False),
            h.result())
    # the swap demonstrably changed the weights in flight
    assert h_old.result().energy.sum() != h_new.result().energy.sum()


def test_mixed_recurrent_graph_parity(lif_surrogate, crossbar_dataset):
    """The acceptance graph — crossbar MAC front-end -> LIF readout with
    recurrent inhibition — served next to plain SNN requests."""
    from repro.core.predictors import PredictorBank
    rng = np.random.default_rng(3)
    xw = rng.integers(-1, 2, (20, 8)).astype(np.float32)
    lw = (rng.normal(0, 0.5, (8, 6)) * 2.2).astype(np.float32)
    inhib = -0.6 * (1 - np.eye(6, dtype=np.float32))
    spec = graph_spec([crossbar_layer(xw),
                       lif_layer(lw, jnp.asarray(PARAMS, jnp.float32))],
                      edges=[recurrent_edge(1, 1, inhib)])
    banks = {"lif": lif_surrogate,
             "crossbar": PredictorBank("crossbar",
                                       families=("mean", "linear")
                                       ).fit(crossbar_dataset)}
    seqs = [(rng.integers(-1, 2, (t, b, 20)) * 0.8).astype(np.float32)
            for t, b in [(20, 2), (11, 1)]]
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    handles = [srv.submit(spec, x, surrogates=banks) for x in seqs]
    srv.run_until_idle()
    for x, h in zip(seqs, handles):
        solo = lasana.simulate(spec, x, surrogates=banks,
                               record_hidden=False)
        _assert_request_parity(solo, h.result())


def test_annotation_mode_parity(lif_surrogate, shared_spec):
    rng = np.random.default_rng(4)
    x = _stim(rng, 13, 2)
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    h = srv.submit(shared_spec, x, surrogates=lif_surrogate,
                   mode="annotation")
    srv.run_until_idle()
    solo = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                           mode="annotation", record_hidden=False)
    _assert_request_parity(solo, h.result())


# --- admission control --------------------------------------------------------

def test_round_robin_tenants_no_starvation(lif_surrogate, shared_spec):
    """A chatty tenant (6 queued requests) cannot starve another: the
    second tenant's requests are seated in the very next admission round
    even though they were submitted last."""
    rng = np.random.default_rng(5)
    srv = SimServer(ServeConfig(slot_widths=(2,), chunk_ticks=CHUNK,
                                max_in_flight=2))
    order = []
    def submit(tenant):
        h = srv.submit(shared_spec, _stim(rng, CHUNK, 1),
                       surrogates=lif_surrogate, tenant=tenant)
        h._on_chunk = lambda rec, hid=h.id: order.append(hid)
        return h
    chatty = [submit("chatty") for _ in range(6)]
    polite = [submit("polite") for _ in range(2)]
    srv.run_until_idle()
    assert all(h.done for h in chatty + polite)
    # both polite requests finish within the first two rounds (4 slots of
    # work), ahead of chatty's 3rd..6th
    for p in polite:
        assert order.index(p.id) < order.index(chatty[2].id)
    assert srv.stats()["wait_chunks_max"] >= 1   # someone actually queued


def test_backpressure_and_validation(lif_surrogate, shared_spec):
    rng = np.random.default_rng(6)
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                max_queue=2))
    ok = [srv.submit(shared_spec, _stim(rng, CHUNK, 1),
                     surrogates=lif_surrogate) for _ in range(2)]
    with pytest.raises(ServerBusy, match="queue full"):
        srv.submit(shared_spec, _stim(rng, CHUNK, 1),
                   surrogates=lif_surrogate)
    # malformed requests fail synchronously, never enter the queue
    with pytest.raises(ValueError, match="exceeds the widest"):
        srv.submit(shared_spec, _stim(rng, CHUNK, 8),
                   surrogates=lif_surrogate)
    with pytest.raises(ValueError, match="fan_in"):
        srv.submit(shared_spec, np.zeros((4, 1, 5), np.float32),
                   surrogates=lif_surrogate)
    with pytest.raises(KeyError, match="no spec registered"):
        srv.submit("nope", _stim(rng, CHUNK, 1),
                   surrogates=lif_surrogate)
    with pytest.raises(KeyError, match="no surrogate registered"):
        srv.submit(shared_spec, _stim(rng, CHUNK, 1), surrogates="ghost")
    srv.run_until_idle()
    assert all(h.done for h in ok)
    assert srv.stats()["requests_rejected"] == 1


def test_invalid_mode_rejected_synchronously(lif_surrogate, shared_spec):
    """A bad mode raises in submit() — it must never reach the driver
    thread, where the engine's ValueError would have killed it."""
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    with pytest.raises(ValueError, match="mode must be one of"):
        srv.submit(shared_spec, np.zeros((4, 1, 12), np.float32),
                   surrogates=lif_surrogate, mode="bogus")


def test_bad_request_does_not_kill_server(lif_surrogate, shared_spec):
    """Per-request fault isolation: a request whose lane creation the
    engine rejects (a direct surrogate object submit cannot cheaply
    validate) fails ITS OWN handle — no hang, no driver-thread death,
    no collateral failures — and the started server keeps serving."""
    rng = np.random.default_rng(11)
    x = _stim(rng, 12, 1)
    with lasana.serve(slot_widths=(4,), chunk_ticks=CHUNK) as srv:
        good1 = srv.submit(shared_spec, x, surrogates=lif_surrogate,
                           tenant="a")
        bad = srv.submit(shared_spec, _stim(rng, 12, 1),
                         surrogates={"not-a-kind": object()}, tenant="b")
        good1.result(timeout=120)
        with pytest.raises(Exception):
            bad.result(timeout=120)          # fails, never blocks forever
        good2 = srv.submit(shared_spec, x, surrogates=lif_surrogate,
                           tenant="c")       # driver is still alive
        served = good2.result(timeout=120)
        st = srv.stats()
    solo = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                           record_hidden=False)
    _assert_request_parity(solo, served)
    assert st["requests_failed"] == 1
    assert st["requests_in_flight"] == 0     # failed request not leaked


def test_on_chunk_error_fails_only_that_request(lif_surrogate,
                                                shared_spec):
    """A user on_chunk callback raising fails its request, not the
    driver thread or its co-batched neighbours."""
    rng = np.random.default_rng(14)
    x = _stim(rng, 12, 1)

    def boom(rec):
        raise RuntimeError("chunk consumer exploded")

    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    h_bad = srv.submit(shared_spec, _stim(rng, 12, 1),
                       surrogates=lif_surrogate, on_chunk=boom)
    h_good = srv.submit(shared_spec, x, surrogates=lif_surrogate)
    srv.run_until_idle()
    with pytest.raises(RuntimeError, match="chunk consumer exploded"):
        h_bad.result()
    solo = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                           record_hidden=False)
    _assert_request_parity(solo, h_good.result())


def test_idle_lane_retirement_and_surrogate_liveness(lif_surrogate,
                                                     shared_spec):
    """Review fixes, both lane-lifecycle halves: (1) the lane holds the
    directly-passed surrogate alive, so the id()-keyed lane identity
    cannot silently alias a new object at a recycled address; (2) lanes
    idle for lane_idle_rounds rounds are retired — dropping key and
    reference together, bounding the lane table — and re-creation is
    compile-free because the engine keeps its compiled programs."""
    import copy
    import gc
    import weakref
    rng = np.random.default_rng(12)
    x = _stim(rng, CHUNK, 1)
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                lane_idle_rounds=3))
    dup = copy.copy(lif_surrogate)
    wr = weakref.ref(dup)
    h = srv.submit(shared_spec, x, surrogates=dup)
    del dup
    srv.run_until_idle()
    h.result()
    gc.collect()
    assert wr() is not None                  # lane pins the surrogate
    assert srv.stats()["n_lanes"] == 1
    # solo reference now: its mono program lands on the shared engine
    # BEFORE the compile-count snapshot the retirement path must hold
    solo = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                           record_hidden=False)
    compiles = srv.compile_count()
    for _ in range(3):                       # idle rounds -> retirement
        assert not srv.step()
    gc.collect()
    assert wr() is None                      # key + reference both gone
    st = srv.stats()
    assert st["n_lanes"] == 0 and st["lanes_retired"] == 1
    h2 = srv.submit(shared_spec, x, surrogates=lif_surrogate)
    srv.run_until_idle()
    _assert_request_parity(solo, h2.result())
    assert srv.compile_count() == compiles   # re-created, zero recompiles


def test_lifecycle_guards(shared_spec):
    srv = SimServer()
    srv.start()
    with pytest.raises(RuntimeError, match="driver thread"):
        srv.run_until_idle()
    srv.close()
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(shared_spec, np.zeros((1, 1, 12), np.float32),
                   surrogates="lif")


# --- store + buckets ----------------------------------------------------------

def test_artifact_store_versioning(lif_surrogate):
    store = ArtifactStore()
    assert store.register("lif", lif_surrogate) == 1
    assert store.register("lif", lif_surrogate) == 2
    assert store.register("lif", lif_surrogate, version=9) == 9
    assert store.register("lif", lif_surrogate) == 10
    assert store.resolve("lif")[0] == ("lif", 10)          # latest
    assert store.resolve("lif@2")[0] == ("lif", 2)         # pinned
    assert store.get("lif", 2) is store.get("lif", 1)
    assert store.names() == ["lif"] and store.versions("lif") == [1, 2, 9,
                                                                  10]
    with pytest.raises(ValueError, match="immutable"):
        store.register("lif", lif_surrogate, version=2)
    with pytest.raises(ValueError, match="'@'-free"):
        store.register("a@b", lif_surrogate)
    with pytest.raises(KeyError, match="not registered"):
        store.resolve("lif@3")
    with pytest.raises(KeyError):
        store.resolve("ghost")
    assert parse_ref("a@3") == ("a", 3) and parse_ref("a") == ("a", None)
    with pytest.raises(ValueError, match="not an integer"):
        parse_ref("a@b")
    with pytest.raises(ValueError, match="bad surrogate ref"):
        parse_ref("@3")


def test_bucket_policy_quantization(shared_spec):
    pol = BucketPolicy(slot_widths=(8, 2), chunk_ticks=4)   # sorts
    assert pol.slot_widths == (2, 8) and pol.max_width == 8
    assert [pol.width_for(b) for b in (1, 2, 3, 8)] == [2, 2, 8, 8]
    with pytest.raises(ValueError, match="exceeds the widest"):
        pol.width_for(9)
    with pytest.raises(ValueError, match="slot_widths"):
        BucketPolicy(slot_widths=())
    with pytest.raises(ValueError, match="chunk_ticks"):
        BucketPolicy(chunk_ticks=0)
    key = spec_content_key(shared_spec)
    assert pol.bucket_for(key, 2).key == (key, 2, 4)
    # content keys: rebuilt-equal specs collapse, value changes split
    assert spec_content_key(_make_spec(0)) == key
    assert spec_content_key(_make_spec(1)) != key
    perturbed = snn_spec(
        [np.asarray(l.weight) * 1.01 for l in shared_spec.layers],
        [l.params for l in shared_spec.layers])
    assert spec_content_key(perturbed) != key


def test_stats_report(lif_surrogate, shared_spec):
    rng = np.random.default_rng(8)
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    srv.register_surrogate("lif", lif_surrogate)
    hs = [srv.submit(shared_spec, _stim(rng, CHUNK, 1), surrogates="lif")
          for _ in range(3)]
    depth = srv.stats()["queue_depth_by_bucket"]
    assert sum(depth.values()) == 3 and len(depth) == 1
    srv.run_until_idle()
    st = srv.stats()
    assert all(h.done for h in hs)
    assert st["requests_submitted"] == st["requests_completed"] == 3
    assert st["queue_depth_by_bucket"] == {}
    assert 0.0 < st["batch_occupancy"] <= 1.0
    assert st["requests_per_sec"] > 0 and st["events_per_sec"] >= 0
    assert st["surrogates"] == {"lif": [1]}
    assert st["n_lanes"] == len(st["lanes"]) == 1
    assert st["lanes"][0]["active_requests"] == 0
    assert isinstance(st["compile_count"], int)


# --- wire protocol ------------------------------------------------------------

def test_protocol_stdio_roundtrip(lif_surrogate):
    """The JSON-lines loop end to end over a STARTED server: register a
    spec, run simulate + the continuous-batching simulate_batch op,
    survive a malformed op, report stats, shut down."""
    rng = np.random.default_rng(9)
    w1 = (rng.normal(0, 0.8, (6, 5))).astype(np.float32)
    w2 = (rng.normal(0, 0.8, (5, 3))).astype(np.float32)
    script = [
        {"op": "register_spec", "name": "net",
         "snn": {"weights": [w1.tolist(), w2.tolist()],
                 "params": [PARAMS, PARAMS]}},
        {"op": "simulate", "id": "r0", "spec": "net", "surrogate": "lif",
         "stimulus_spikes": {"t": 12, "b": 2, "rate": 0.25, "seed": 5}},
        {"op": "simulate_batch", "requests": [
            {"id": f"b{i}", "spec": "net", "surrogate": "lif",
             "tenant": f"t{i}",
             "stimulus_spikes": {"t": 6 + 3 * i, "b": 1, "seed": i}}
            for i in range(3)]},
        {"op": "simulate", "id": "bad", "spec": "ghost",
         "surrogate": "lif", "stimulus_spikes": {"t": 4, "b": 1}},
        {"op": "stats"},
        {"op": "shutdown"},
        {"op": "never_reached"},
    ]
    fin = io.StringIO("\n".join(json.dumps(o) for o in script) + "\n")
    fout = io.StringIO()
    with lasana.serve(slot_widths=(4,), chunk_ticks=CHUNK) as srv:
        srv.register_surrogate("lif", lif_surrogate)
        handled = run_stdio(srv, fin, fout)
    assert handled == 6                       # shutdown stops the loop
    resps = [json.loads(l) for l in fout.getvalue().splitlines()]
    assert [r["ok"] for r in resps] == [True, True, True, False, True,
                                        True]
    assert resps[1]["id"] == "r0" and resps[1]["ticks"] == 12
    assert resps[1]["energy_j"] > 0
    assert np.asarray(resps[1]["outputs"]).shape == (2, 3)
    batch = resps[2]["results"]
    assert [r["id"] for r in batch] == ["b0", "b1", "b2"]
    assert [r["ticks"] for r in batch] == [6, 9, 12]
    assert resps[3]["id"] == "bad" and "no spec" in resps[3]["error"]
    st = resps[4]["stats"]
    assert st["requests_completed"] == 4 and st["compile_count"] >= 1


def test_protocol_spec_registry_survives_reconnect(lif_surrogate):
    """Review fixes on the wire path: (1) spec names registered on one
    connection resolve on the next — _submit falls back to the server-
    side registry; (2) a simulate_batch that fails partway still
    collects the already-submitted requests' results."""
    rng = np.random.default_rng(13)
    w = rng.normal(0, 0.8, (6, 3)).astype(np.float32)
    conn1 = [{"op": "register_spec", "name": "net",
              "snn": {"weights": [w.tolist()], "params": [PARAMS]}}]
    conn2 = [
        {"op": "simulate", "id": "r", "spec": "net", "surrogate": "lif",
         "stimulus_spikes": {"t": 8, "b": 1, "seed": 3}},
        {"op": "simulate_batch", "requests": [
            {"id": "ok", "spec": "net", "surrogate": "lif",
             "stimulus_spikes": {"t": 8, "b": 1, "seed": 4}},
            {"id": "bad", "spec": "ghost", "surrogate": "lif",
             "stimulus_spikes": {"t": 8, "b": 1}}]},
    ]
    feed = lambda ops: io.StringIO(
        "\n".join(json.dumps(o) for o in ops) + "\n")
    out1, out2 = io.StringIO(), io.StringIO()
    with lasana.serve(slot_widths=(4,), chunk_ticks=CHUNK) as srv:
        srv.register_surrogate("lif", lif_surrogate)
        run_stdio(srv, feed(conn1), out1)    # first "connection"
        run_stdio(srv, feed(conn2), out2)    # reconnect: fresh specs dict
    r1 = [json.loads(l) for l in out1.getvalue().splitlines()]
    r2 = [json.loads(l) for l in out2.getvalue().splitlines()]
    assert r1[0]["ok"]
    assert r2[0]["ok"] and r2[0]["ticks"] == 8        # registry fallback
    batch = r2[1]
    assert not batch["ok"] and "ghost" in batch["error"]
    assert [r["id"] for r in batch["results"]] == ["ok"]  # partials kept
    assert batch["results"][0]["ticks"] == 8
