"""Optimizer substrate: AdamW convergence, clipping, int8 error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # minimal CPU container
    from _hyp_fallback import given, settings, st

from repro.optim import (AdamW, AdamWConfig, clip_by_global_norm,
                         compress_decompress, dequantize_int8, global_norm,
                         quantize_int8, warmup_cosine)


def _run_adamw(compress: bool, steps=200):
    cfg = AdamWConfig(lr=0.05, warmup_steps=10, total_steps=steps,
                      weight_decay=0.0, compress_grads=compress)
    opt = AdamW(cfg)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    state = opt.init(params)

    @jax.jit
    def step(params, state, i):
        def loss(p):
            return jnp.mean(jnp.square(p["w"] - target))
        g = jax.grad(loss)(params)
        return opt.update(g, state, params, jnp.asarray(i))

    for i in range(steps):
        params, state, m = step(params, state, i)
    return float(jnp.mean(jnp.square(params["w"] - target)))


def test_adamw_converges():
    assert _run_adamw(False) < 1e-3


def test_adamw_converges_with_compression():
    """int8 error feedback must not break convergence (1-bit-Adam property)."""
    assert _run_adamw(True) < 5e-3


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 30


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 500), st.floats(1e-6, 1e4))
def test_quantize_roundtrip_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, size=(64,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert np.all(err <= float(s) * 0.5 + 1e-12)


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.asarray([1e-4, 0.5, -0.25], jnp.float32)}
    err = {"w": jnp.zeros((3,))}
    out, new_err = compress_decompress(g, err)
    # residual == what was lost this round
    np.testing.assert_allclose(
        np.asarray(g["w"]) - np.asarray(out["w"]), np.asarray(new_err["w"]),
        atol=1e-7)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) < 0.11
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(sched(jnp.asarray(100))) <= 0.11
