"""Sharding rule table properties."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # minimal CPU container
    from _hyp_fallback import given, settings, st

import jax
from jax.sharding import Mesh

from repro.sharding import ShardingRules, train_rules


def _mesh_2d():
    d = jax.devices()[0]
    arr = np.array([[d]])
    return Mesh(arr, ("data", "model"))


def test_spec_dedups_mesh_axes():
    rules = ShardingRules(rules={"a": "x", "b": "x", "c": ("x", "y")})
    spec = rules.spec(("a", "b", "c"))
    # 'x' consumed by 'a'; 'b' replicated; 'c' gets only 'y'
    assert spec == jax.sharding.PartitionSpec("x", None, "y")


@settings(max_examples=40, deadline=None)
@given(dim=st.integers(1, 64), size=st.sampled_from([2, 4, 8, 16]))
def test_spec_for_shape_divisibility(dim, size):
    d = jax.devices()[0]
    mesh = Mesh(np.array([d]).reshape(1, 1), ("data", "model"))
    # fake sizes via a rules table probe: use the pure logic on dict sizes
    rules = ShardingRules(rules={"h": "model"})
    sizes = {"data": 1, "model": size}

    # re-implement the check the production mesh enforces
    spec = rules.spec_for_shape_with_sizes if hasattr(
        rules, "spec_for_shape_with_sizes") else None
    # direct: axis kept iff divisible
    keep = dim % size == 0
    p = rules.spec_for_shape(_FakeMesh(sizes), ("h",), (dim,))
    got_kept = len(p) > 0 and p[0] == "model"
    assert got_kept == keep


class _FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self._shape = tuple(sizes.values())

    @property
    def devices(self):
        class _D:
            pass
        d = _D()
        d.shape = self._shape
        return d


def test_train_rules_have_expected_axes():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = train_rules(mesh)
    p = rules.spec_for_shape(mesh, ("batch", None), (256, 128))
    assert p == jax.sharding.PartitionSpec(("pod", "data"))
    p = rules.spec_for_shape(mesh, ("embed", "mlp"), (4096, 12800))
    assert p == jax.sharding.PartitionSpec(("pod", "data"), "model")
    # kv_heads=2 on 16-way model axis -> replicated
    p = rules.spec_for_shape(mesh, ("embed", "kv_heads", None),
                             (4096, 2, 128))
    assert p == jax.sharding.PartitionSpec(("pod", "data"))


def test_batch_dim_one_replicates():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = train_rules(mesh)
    p = rules.spec_for_shape(mesh, ("batch", None, None), (1, 1, 512))
    assert p == jax.sharding.PartitionSpec()
