"""MoE dispatch invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # minimal CPU container
    from _hyp_fallback import given, settings, st

from repro.configs.base import AttentionKind, Family, ModelConfig, MoEConfig
from repro.models import moe as moe_mod
from repro.models.params import materialize


def _cfg(e=8, k=2, cf=1.25, router="softmax", shared=0):
    return ModelConfig(
        name="t", family=Family.MOE, n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=64,
        moe=MoEConfig(n_experts=e, top_k=k, n_shared=shared, d_ff_expert=48,
                      capacity_factor=cf, router=router))


def _params(cfg, key):
    return materialize(key, moe_mod.moe_specs(cfg))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), router=st.sampled_from(["softmax", "sigmoid"]))
def test_moe_finite_and_shaped(seed, router):
    cfg = _cfg(router=router, shared=1)
    key = jax.random.PRNGKey(seed)
    params = _params(cfg, key)
    x = jax.random.normal(key, (2, 16, 32), jnp.bfloat16)
    y, aux = moe_mod.moe_ffn(params, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y, np.float32)))
    assert np.isfinite(float(aux))


def test_dispatch_respects_capacity():
    """No expert processes more than C assignments."""
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 4, 64), jnp.int32)
    cap = 8
    dest, ok = moe_mod._dispatch_indices(ids, 4, cap)
    dest = np.asarray(dest)
    kept = dest[dest < 4 * cap]
    counts = np.bincount(kept // cap, minlength=4)
    assert np.all(counts <= cap)
    # slots unique
    assert len(np.unique(kept)) == len(kept)


def test_dispatch_keeps_everything_under_large_capacity():
    ids = jnp.asarray(np.random.default_rng(1).integers(0, 8, 128), jnp.int32)
    dest, ok = moe_mod._dispatch_indices(ids, 8, 128)
    assert bool(np.all(np.asarray(ok)))


def test_moe_equals_dense_mixture_when_capacity_ample():
    """top_k == n_experts + huge capacity -> exact softmax mixture of FFNs."""
    cfg = _cfg(e=4, k=4, cf=64.0)
    key = jax.random.PRNGKey(3)
    params = _params(cfg, key)
    x = jax.random.normal(key, (1, 8, 32), jnp.float32)
    y, _ = moe_mod.moe_ffn(params, x, cfg)

    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    w = jax.nn.softmax(logits, axis=-1)
    dense = 0
    for e in range(4):
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"][e])
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"][e])
        h = jax.nn.silu(g) * u
        o = jnp.einsum("bsf,fd->bsd", h, params["w_down"][e])
        dense = dense + w[..., e : e + 1] * o
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(dense, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_group_count_changes_capacity_not_semantics():
    cfg = _cfg(e=4, k=1, cf=8.0)
    key = jax.random.PRNGKey(4)
    params = _params(cfg, key)
    x = jax.random.normal(key, (2, 16, 32), jnp.float32)
    y1, _ = moe_mod.moe_ffn(params, x, cfg, n_groups=1)
    y2, _ = moe_mod.moe_ffn(params, x, cfg, n_groups=2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-2, atol=2e-2)
