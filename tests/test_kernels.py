"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles, interpret=True execution (kernel bodies run in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.circuits import LIFNeuron
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [64, 300, 1024])
@pytest.mark.parametrize("f,h1,h2", [(41, 100, 50), (67, 100, 50), (16, 32, 16)])
def test_mlp_surrogate_shapes(n, f, h1, h2):
    key = jax.random.PRNGKey(n + f)
    ks = jax.random.split(key, 7)
    x = jax.random.normal(ks[0], (n, f))
    w1 = jax.random.normal(ks[1], (f, h1)) * 0.1
    b1 = jax.random.normal(ks[2], (h1,)) * 0.1
    w2 = jax.random.normal(ks[3], (h1, h2)) * 0.1
    b2 = jax.random.normal(ks[4], (h2,)) * 0.1
    w3 = jax.random.normal(ks[5], (h2, 1)) * 0.1
    b3 = jax.random.normal(ks[6], (1,)) * 0.1
    got = ops.mlp_surrogate(x, w1, b1, w2, b2, w3, b3)
    want = ref.mlp_surrogate_ref(x, w1, b1, w2, b2, w3, b3)[:, 0]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlp_surrogate_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 41)).astype(dtype)
    w1 = (jax.random.normal(key, (41, 100)) * 0.1).astype(jnp.float32)
    b1 = jnp.zeros((100,))
    w2 = (jax.random.normal(key, (100, 50)) * 0.1).astype(jnp.float32)
    b2 = jnp.zeros((50,))
    w3 = (jax.random.normal(key, (50, 1)) * 0.1).astype(jnp.float32)
    b3 = jnp.zeros((1,))
    got = ops.mlp_surrogate(x, w1, b1, w2, b2, w3, b3)
    want = ref.mlp_surrogate_ref(x.astype(jnp.float32), w1, b1, w2, b2, w3,
                                 b3)[:, 0]
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def _head_stack(key, p, f, h1=100, h2=50):
    ks = jax.random.split(key, 10)
    return dict(
        x_mu=jax.random.normal(ks[0], (p, f)) * 0.3,
        x_sd=1.0 + jax.random.uniform(ks[1], (p, f)),
        y_mu=jax.random.normal(ks[2], (p, 1)),
        y_sd=1.0 + jax.random.uniform(ks[3], (p, 1)),
        w1=jax.random.normal(ks[4], (p, f, h1)) * 0.1,
        b1=jax.random.normal(ks[5], (p, h1)) * 0.1,
        w2=jax.random.normal(ks[6], (p, h1, h2)) * 0.1,
        b2=jax.random.normal(ks[7], (p, h2)) * 0.1,
        w3=jax.random.normal(ks[8], (p, h2, 1)) * 0.1,
        b3=jax.random.normal(ks[9], (p, 1)) * 0.1)


@pytest.mark.parametrize("n", [256, 300, 97])   # incl. N % block_n != 0
@pytest.mark.parametrize("p,f", [(4, 11), (2, 41), (7, 13)])
def test_mlp_surrogate_heads_matches_per_head(n, p, f):
    """ISSUE-5 multi-head kernel: P stacked heads over one feature block
    == P single-head kernel calls (ragged N handled by ops padding)."""
    key = jax.random.PRNGKey(n * 7 + p)
    s = _head_stack(key, p, f)
    x = jax.random.normal(jax.random.PRNGKey(n), (n, f))
    got = ops.mlp_surrogate_heads(
        x, s["x_mu"], s["x_sd"], s["y_mu"], s["y_sd"],
        s["w1"], s["b1"], s["w2"], s["b2"], s["w3"], s["b3"])
    assert got.shape == (p, n)
    for i in range(p):
        xs = (x - s["x_mu"][i]) / s["x_sd"][i]
        want = ops.mlp_surrogate(xs, s["w1"][i], s["b1"][i], s["w2"][i],
                                 s["b2"][i], s["w3"][i], s["b3"][i])
        want = want * s["y_sd"][i, 0] + s["y_mu"][i, 0]
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_mlp_surrogate_heads_padding_is_inert():
    """Feature/hidden padding must contribute exactly nothing: a 1-column
    widening of the weights with zeros leaves every output unchanged
    (guards the x_sd ones-padding — a zero pad would inject NaNs)."""
    key = jax.random.PRNGKey(3)
    p, f, n = 3, 11, 64
    s = _head_stack(key, p, f, h1=32, h2=16)
    x = jax.random.normal(jax.random.PRNGKey(9), (n, f))
    base = ops.mlp_surrogate_heads(
        x, s["x_mu"], s["x_sd"], s["y_mu"], s["y_sd"],
        s["w1"], s["b1"], s["w2"], s["b2"], s["w3"], s["b3"])
    xw = jnp.pad(x, ((0, 0), (0, 1)), constant_values=123.0)
    widened = ops.mlp_surrogate_heads(
        xw, jnp.pad(s["x_mu"], ((0, 0), (0, 1))),
        jnp.pad(s["x_sd"], ((0, 0), (0, 1)), constant_values=1.0),
        s["y_mu"], s["y_sd"],
        jnp.pad(s["w1"], ((0, 0), (0, 1), (0, 0))), s["b1"],
        s["w2"], s["b2"], s["w3"], s["b3"])
    np.testing.assert_array_equal(np.asarray(base), np.asarray(widened))


def test_predict_heads_kernel_path_matches_einsum(monkeypatch):
    """REPRO_FUSED_KERNEL=1 routes stacked 3-layer MLP heads through the
    Pallas kernel; results match the default einsum path."""
    from repro.core.surrogate import _predict_mlp_stacked
    key = jax.random.PRNGKey(17)
    p, f, n = 3, 10, 45
    s = _head_stack(key, p, f)
    heads = [{k2: s[k1][i] for k1, k2 in
              (("w1", "w0"), ("b1", "b0"), ("w2", "w1"), ("b2", "b1"),
               ("w3", "w2"), ("b3", "b2"), ("x_mu", "x_mu"),
               ("x_sd", "x_sd"), ("y_mu", "y_mu"), ("y_sd", "y_sd"))}
             for i in range(p)]
    x = jax.random.normal(jax.random.PRNGKey(5), (n, f))
    monkeypatch.delenv("REPRO_FUSED_KERNEL", raising=False)
    einsum = _predict_mlp_stacked(heads, x)
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    kernel = _predict_mlp_stacked(heads, x)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(einsum),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,n_in", [(64, 32), (123, 32), (256, 16)])
def test_crossbar_target(n, n_in):
    key = jax.random.PRNGKey(n)
    v = jax.random.uniform(key, (n, n_in), minval=-0.8, maxval=0.8)
    w = jax.random.randint(key, (n, n_in + 1), -1, 2).astype(jnp.float32)
    tgt, tau = ops.crossbar_target(v, w)
    tgt_r, tau_r = ref.crossbar_target_ref(v, w)
    np.testing.assert_allclose(tgt, tgt_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(tau, tau_r, rtol=1e-6)


@pytest.mark.parametrize("n", [64, 200, 512])
@pytest.mark.parametrize("seed", [0, 1])
def test_lif_step_matches_golden(n, seed):
    circ = LIFNeuron()
    key = jax.random.PRNGKey(seed)
    st = jnp.abs(jax.random.normal(key, (n, 3))) * 0.3
    x = circ.sample_inputs(key, (n,))
    p = circ.sample_params(key, n)
    ns_k, obs_k = ops.lif_step(st, x, p)
    ns_r, obs_r = ref.lif_step_ref(st, x, p)
    np.testing.assert_allclose(ns_k, ns_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(obs_k["energy"], obs_r["energy"],
                               rtol=1e-5, atol=1e-22)
    np.testing.assert_array_equal(np.asarray(obs_k["spiked"]),
                                  np.asarray(obs_r["spiked"]))
    np.testing.assert_allclose(obs_k["latency"], obs_r["latency"],
                               rtol=1e-5)


@pytest.mark.parametrize("n", [64, 256, 300, 512, 1024])
@pytest.mark.parametrize("block_n", [128, 256])
def test_lif_scan_bitwise_matches_circuit_step(n, block_n):
    """The kernel docstring contract: ``lif_scan`` must match
    ``circuits.LIFNeuron.step`` BIT-FOR-BIT in fp32 (both as compiled XLA
    programs — the oracle is jitted exactly as dataset generation runs it;
    eager per-op execution may differ by FMA contraction)."""
    circ = LIFNeuron()
    key = jax.random.PRNGKey(n + block_n)
    k1, k2, k3 = jax.random.split(key, 3)
    st = jnp.abs(jax.random.normal(k1, (n, 3))).astype(jnp.float32) * 0.3
    x = circ.sample_inputs(k2, (n,)).astype(jnp.float32)
    p = circ.sample_params(k3, n)
    ns_k, obs_k = ops.lif_step(st, x, p, block_n=block_n)
    ns_g, obs_g = jax.jit(circ.step)(st, x, p)
    assert ns_k.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(ns_k), np.asarray(ns_g))
    for field in ("output", "energy", "latency", "spiked"):
        np.testing.assert_array_equal(np.asarray(obs_k[field]),
                                      np.asarray(obs_g[field]),
                                      err_msg=field)


@pytest.mark.parametrize("dtype_state", [jnp.float32])
def test_lif_scan_fp32_state_dtype_preserved(dtype_state):
    """Padding in the ops wrapper must not change dtypes or the valid rows."""
    circ = LIFNeuron()
    key = jax.random.PRNGKey(11)
    n = 100                                     # forces padding to block
    st = jnp.zeros((n, 3), dtype_state)
    x = circ.sample_inputs(key, (n,)).astype(jnp.float32)
    p = circ.sample_params(key, n)
    ns, obs = ops.lif_step(st, x, p, block_n=64)
    assert ns.shape == (n, 3) and ns.dtype == jnp.float32
    assert obs["spiked"].dtype == jnp.bool_
    ns_ref, obs_ref = jax.jit(circ.step)(st, x, p)
    np.testing.assert_array_equal(np.asarray(ns), np.asarray(ns_ref))
    np.testing.assert_array_equal(np.asarray(obs["energy"]),
                                  np.asarray(obs_ref["energy"]))


@pytest.mark.parametrize("s,d,bq", [(256, 64, 128), (512, 64, 128),
                                    (256, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(s, d, bq, dtype):
    key = jax.random.PRNGKey(s + d)
    shape = (1, 2, s, d)
    q = jax.random.normal(key, shape).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), shape).astype(dtype)
    got = ops.flash_attention(q, k, v, block_q=bq, block_k=min(bq, 128))
    want = ref.flash_attention_ref(
        q.reshape(2, s, d), k.reshape(2, s, d), v.reshape(2, s, d)
    ).reshape(1, 2, s, d)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# --- padding inertness (the x_sd pads-with-ones bug class) ------------------
#
# Every ops wrapper pads N to the block size and F/H to the 128-lane
# boundary. Padded feature columns MUST carry x_sd = 1 (a zero pad divides
# by zero in the standardizer and poisons the whole block with NaNs) and
# zero weights; padded rows must never leak into valid rows. One named
# regression test per kernel.


def test_mlp_surrogate_heads_padding_sweep_is_inert():
    """F-to-128 sweep for mlp_surrogate_heads: widening the features with
    garbage columns (x_sd=1 / zero-weight pads), including ACROSS the 128
    lane boundary (120 -> 129 repads 128 -> 256), changes nothing at
    ragged and block-multiple N."""
    for n in (64, 97):
        for f, extra in ((11, 1), (120, 9), (127, 2)):
            s = _head_stack(jax.random.PRNGKey(21), 3, f, h1=32, h2=16)
            x = jax.random.normal(jax.random.PRNGKey(f), (n, f))
            base = ops.mlp_surrogate_heads(
                x, s["x_mu"], s["x_sd"], s["y_mu"], s["y_sd"],
                s["w1"], s["b1"], s["w2"], s["b2"], s["w3"], s["b3"])
            xw = jnp.pad(x, ((0, 0), (0, extra)), constant_values=7.5)
            widened = ops.mlp_surrogate_heads(
                xw, jnp.pad(s["x_mu"], ((0, 0), (0, extra))),
                jnp.pad(s["x_sd"], ((0, 0), (0, extra)),
                        constant_values=1.0),
                s["y_mu"], s["y_sd"],
                jnp.pad(s["w1"], ((0, 0), (0, extra), (0, 0))), s["b1"],
                s["w2"], s["b2"], s["w3"], s["b3"])
            assert np.isfinite(np.asarray(widened)).all()
            np.testing.assert_array_equal(np.asarray(base),
                                          np.asarray(widened),
                                          err_msg=f"n={n} f={f}+{extra}")


def test_crossbar_target_n_padding_is_inert():
    """N-to-block sweep for crossbar_mvm: rows are independent, so the
    valid rows of a ragged-N call must equal the same rows computed alone
    (padded rows never leak back)."""
    key = jax.random.PRNGKey(33)
    v = jax.random.uniform(key, (300, 32), minval=-0.8, maxval=0.8)
    w = jax.random.randint(key, (300, 33), -1, 2).astype(jnp.float32)
    for n in (1, 5, 256, 300):
        tgt, tau = ops.crossbar_target(v[:n], w[:n])
        assert tgt.shape == (n,) and np.isfinite(np.asarray(tgt)).all()
        tgt_f, tau_f = ops.crossbar_target(v, w)
        np.testing.assert_array_equal(np.asarray(tgt),
                                      np.asarray(tgt_f[:n]))
        np.testing.assert_array_equal(np.asarray(tau),
                                      np.asarray(tau_f[:n]))


def test_network_tick_x_sd_pads_with_ones(lif_bank):
    """The tick megakernel's pack padding carries x_sd = 1 in every padded
    feature column — the named regression for the pads-with-zeros bug
    class — and the padded pack stays NaN-free end to end."""
    from repro.kernels import tick_megakernel as mk
    pack, _ = mk.pack_heads(lif_bank.to_surrogate())
    assert pack is not None
    pp = mk._padded_pack(pack)
    for stk in ("a", "t"):
        f = pack[stk]["x_sd"].shape[1]
        pad = np.asarray(pp[stk]["x_sd"][:, f:])
        assert pad.shape[1] > 0          # the bench widths ARE ragged
        np.testing.assert_array_equal(pad, np.ones_like(pad))
        np.testing.assert_array_equal(np.asarray(pp[stk]["w0"][:, f:]), 0.0)


def test_network_tick_n_padding_is_inert(lif_bank):
    """N-to-block sweep for the tick megakernel: circuits are independent,
    so the valid rows of a ragged-N launch equal the same rows of a larger
    launch — pad rows (changed=False) contribute nothing."""
    from repro.core.wrapper import init_state
    from repro.kernels import tick_megakernel as mk
    pack, layout = mk.pack_heads(lif_bank.to_surrogate())
    rng = np.random.default_rng(4)
    n_big = 12
    params = jnp.asarray(
        rng.uniform(0.3, 0.7, (n_big, 4)).astype(np.float32))
    state = init_state(n_big, params)._replace(
        v=jnp.asarray(rng.uniform(0, 1, n_big).astype(np.float32)),
        t_last=jnp.asarray(
            rng.choice([0.0, 5.0], n_big).astype(np.float32)))
    changed = jnp.asarray(rng.random(n_big) < 0.7)
    x = jnp.asarray(rng.uniform(-1, 1, (n_big, 3)).astype(np.float32))
    t = jnp.float32(30.0)

    def tick(n):
        return mk.network_tick(
            pack, state.v[:n], state.o[:n], state.t_last[:n], params[:n],
            changed[:n], x[:n], t, jnp.zeros((n,), jnp.float32),
            circuit="lif", clock_ns=5.0, layout=layout, spiking=True)

    big = tick(n_big)
    for n in (1, 5, n_big):
        for got, ref_full in zip(tick(n), big):
            assert np.isfinite(np.asarray(got)).all()
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(ref_full[:n]))


def test_flash_attention_is_causal():
    """Future tokens must not influence the output."""
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 1, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 256, 64))
    o1 = ops.flash_attention(q, k, v)
    k2 = k.at[:, :, 200:].set(99.0)
    v2 = v.at[:, :, 200:].set(-99.0)
    o2 = ops.flash_attention(q, k2, v2)
    np.testing.assert_allclose(o1[:, :, :200], o2[:, :, :200],
                               rtol=1e-5, atol=1e-5)
