"""Static-analysis gate tests: each seeded defect is caught, the repo is
clean (docs/analysis.md).

Two halves. The seeded-defect fixtures feed the auditor/lint a program
or source snippet containing exactly one planted violation — a tick
exceeding its dispatch ceiling, a silently-dropped donation, an fp64
leak, a host callback inside a scan body, an ``id()``-keyed cache, an
unguarded ``Lane`` field write — and assert a finding naming the
entrypoint/field. The clean-repo tests run the same passes over the
real tree and assert zero findings, which is what CI's ``analysis`` leg
enforces (tools/check_programs.py, tools/check_threads.py)."""

import functools
import textwrap

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_audit, thread_lint
from repro.analysis.jaxpr_audit import TracedEntry
from repro.analysis.thread_lint import ClassDiscipline
from repro.kernels import ops


def _checks(findings):
    return {f.check for f in findings}


# --- seeded defects: program auditor -------------------------------------------


def test_dispatch_budget_excess_caught():
    """A tick making four stacked dispatches against a ceiling of three
    must fail, naming the entrypoint — a regen cannot lift ceilings."""
    def fat_tick(x):
        for _ in range(4):
            ops.record_dispatch("predict_heads")
            x = x + 1.0
        return x

    entry = TracedEntry(fn=fat_tick, args=(jnp.zeros((3,), jnp.float32),),
                        max_dispatch={"predict_heads": 3})
    metrics, findings = jaxpr_audit.audit_entry("fat_tick", entry)
    assert metrics.dispatches == {"predict_heads": 4}
    assert [f.check for f in findings] == ["dispatch-budget"]
    assert findings[0].entry == "fat_tick"
    assert "4" in findings[0].message and "3" in findings[0].message


def test_dropped_donation_caught():
    """donate_argnums leaves that XLA cannot alias (shape/dtype mismatch
    with every output) are silently copied — the auditor must flag the
    drop rather than trust the declaration."""
    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(a, b):
        # 'a' aliases the first output; 'b' reduces to a scalar and can
        # alias nothing — a dropped donation
        return a + 1.0, b.sum()

    entry = TracedEntry(fn=step,
                        args=(jnp.zeros((4,), jnp.float32),
                              jnp.zeros((5,), jnp.float32)),
                        donate=(0, 1))
    metrics, findings = jaxpr_audit.audit_entry("leaky_step", entry)
    assert "donation" in _checks(findings)
    assert metrics.donated == 1  # only 'a' actually aliased, not 2
    assert any(f.entry == "leaky_step" for f in findings)


def test_clean_donation_passes():
    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(a, b):
        return a + b, b.sum()

    entry = TracedEntry(fn=step,
                        args=(jnp.zeros((4,), jnp.float32),
                              jnp.zeros((4,), jnp.float32)),
                        donate=(0,))
    metrics, findings = jaxpr_audit.audit_entry("ok_step", entry)
    assert findings == []
    assert metrics.donated == 1


def test_fp64_promotion_caught():
    """An fp64 aval anywhere in a traced hot-path body is a finding."""
    def promoting(x):
        return x.astype(jnp.float64) * 2.0

    with jax.experimental.enable_x64():
        entry = TracedEntry(fn=promoting,
                            args=(jnp.zeros((3,), jnp.float32),))
        _, findings = jaxpr_audit.audit_entry("wide_tick", entry)
    assert "fp64-promotion" in _checks(findings)
    assert any("float64" in f.message for f in findings)


def test_callback_in_scan_caught():
    """A pure_callback inside a scan body host-syncs every tick."""
    def body(carry, _):
        y = jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), carry)
        return carry + y, y

    def run(x0):
        return jax.lax.scan(body, x0, None, length=4)

    entry = TracedEntry(fn=run, args=(jnp.float32(0.0),))
    _, findings = jaxpr_audit.audit_entry("chatty_scan", entry)
    cb = [f for f in findings if f.check == "host-callback"]
    assert cb and "scan body" in cb[0].message


def test_id_keyed_cache_caught():
    src = textwrap.dedent("""
        def _key(self, surrogate, b):
            return (id(surrogate), b)
    """)
    findings = jaxpr_audit.check_cache_key_source(
        src, required=("b",), name="bad-cache")
    assert [f.check for f in findings] == ["cache-key"]
    assert "id(" in findings[0].message
    assert findings[0].entry == "bad-cache"


def test_missing_cache_key_field_caught():
    src = "def _key(self, b):\n    return (b,)\n"
    findings = jaxpr_audit.check_cache_key_source(
        src, required=("b", "structure_key"), name="narrow-cache")
    assert len(findings) == 1
    assert "structure_key" in findings[0].message


def test_env_read_outside_ops_caught(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "rogue.py").write_text(
        "import os\n"
        "SMOKE = os.environ.get('REPRO_BENCH_SMOKE')\n"
        "DIR = os.environ['REPRO_BENCH_DIR']\n"
        "os.environ['XLA_FLAGS'] = 'x'\n")  # a write: allowed
    findings = jaxpr_audit.check_env_discipline(root=tmp_path)
    assert len(findings) == 2
    assert all(f.check == "env-discipline" for f in findings)
    assert all("rogue.py" in f.entry for f in findings)


# --- seeded defects: concurrency lint ------------------------------------------

_LANE_TABLE = {"Lane": ClassDiscipline(
    lock="_lock",
    driver=frozenset({"_carries"}),
    driver_write=frozenset({"g"}),
    locked=frozenset({"_queue"}),
    init=frozenset({"engine"}),
    driver_methods=frozenset({"step"}),
)}


def _lint(src, table=None):
    return thread_lint.lint_source(textwrap.dedent(src),
                                   table or _LANE_TABLE, "fixture.py")


def test_unguarded_lane_field_write_caught():
    findings = _lint("""
        class Lane:
            def submit(self, req):
                self._carries = req      # driver-only state, wrong thread
            def step(self):
                self._carries = None     # fine: driver method
    """)
    assert len(findings) == 1
    f = findings[0]
    assert f.check == "thread-affinity"
    assert "_carries" in f.message and "Lane.submit" in f.entry


def test_locked_field_outside_lock_caught():
    findings = _lint("""
        class Lane:
            def submit(self, req):
                self._queue.append(req)
            def drain(self):
                with self._lock:
                    return list(self._queue)
    """)
    assert len(findings) == 1
    assert findings[0].check == "unguarded-state"
    assert "_queue" in findings[0].message


def test_blocking_call_under_lock_caught():
    findings = _lint("""
        class Lane:
            def step(self):
                with self._lock:
                    self.engine.compile()
    """)
    assert len(findings) == 1
    assert findings[0].check == "blocking-under-lock"
    assert "compile" in findings[0].message


def test_callback_under_lock_caught():
    """RequestHandle._push fires the user's on_chunk — never under a
    server lock (user code re-entering submit() would deadlock)."""
    findings = _lint("""
        class Lane:
            def step(self):
                with self._lock:
                    handle._push(chunk)
    """)
    assert [f.check for f in findings] == ["blocking-under-lock"]
    assert "_push" in findings[0].message


def test_unannotated_field_caught():
    """Table completeness is load-bearing: a new field with no declared
    locking discipline is itself a finding."""
    findings = _lint("""
        class Lane:
            def step(self):
                self.scratch = 1
    """)
    assert [f.check for f in findings] == ["unannotated-field"]
    assert "scratch" in findings[0].message


def test_driver_write_racy_read_tolerated():
    findings = _lint("""
        class Lane:
            def stats(self):
                return self.g            # racy read: tolerated
            def submit(self):
                self.g = 2.0             # foreign write: flagged
    """)
    assert len(findings) == 1
    assert "g" in findings[0].message and "submit" in findings[0].entry


def test_condition_wait_exempt_under_lock():
    table = {"Srv": ClassDiscipline(
        lock="_lock", lock_aliases=frozenset({"_wake"}),
        locked=frozenset({"_queues"}))}
    findings = _lint("""
        class Srv:
            def _drive(self):
                with self._wake:
                    if not self._queues:
                        self._wake.wait(0.1)
    """, table)
    assert findings == []


def test_cross_object_driver_store_caught():
    findings = _lint("""
        class Lane:
            def submit(self, lane):
                lane.g = 1.0
    """)
    assert len(findings) == 1
    assert findings[0].check == "thread-affinity"


# --- the repo itself is clean --------------------------------------------------


def test_repo_thread_lint_clean():
    assert thread_lint.run_lint() == []


def test_repo_cache_keys_clean():
    assert jaxpr_audit.check_cache_keys() == []


def test_repo_env_discipline_clean():
    assert jaxpr_audit.check_env_discipline() == []


def test_repo_program_audit_clean():
    """The full trace-time audit against the frozen budgets — exactly
    what CI's analysis leg runs via tools/check_programs.py."""
    findings = jaxpr_audit.run_audit(jaxpr_audit.load_budgets())
    assert findings == [], "\n".join(str(f) for f in findings)


def test_frozen_budgets_cover_all_entrypoints():
    frozen = jaxpr_audit.load_budgets()
    # builders register at jaxpr_audit import time (module-level decorators)
    registered = set(ops.registered_entrypoints())
    assert registered == set(frozen)
    # the two headline ceilings, asserted against the frozen file itself
    assert sum(frozen["tick_fused_standalone"]["dispatches"].values()) <= 3
    assert frozen["tick_megakernel"]["dispatches"] == {"megakernel_step": 1}
    assert frozen["tick_fused_annotation"]["dispatches"] == {
        "predict_heads": 1}


def test_dispatch_scope_nests_and_restores():
    with ops.dispatch_scope() as outer:
        ops.record_dispatch("a")
        with ops.dispatch_scope() as inner:
            ops.record_dispatch("b")
        ops.record_dispatch("a")
    assert outer == ["a", "a"] and inner == ["b"]
    ops.record_dispatch("dropped")  # no active scope: a no-op
